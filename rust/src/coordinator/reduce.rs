//! Reduced-problem extraction and solution scatter.
//!
//! After a TLFre screening pass, the solver only sees the surviving
//! features. The reduced design is a **zero-copy** [`ScreenedView`] over
//! the full backend matrix — a survivor-index indirection instead of the
//! seed's per-λ column-gathered copy — plus a recomputed group structure
//! over the survivors. Solutions are scattered back into the full
//! coefficient vector; screened positions are exactly zero by the safety
//! guarantee.

use crate::groups::GroupStructure;
use crate::linalg::{DenseMatrix, DesignMatrix, ScreenedView};
use crate::screening::tlfre::TlfreOutcome;

/// A reduced SGL problem, with the bookkeeping to go back to full space.
#[derive(Debug, Clone)]
pub struct ReducedProblem<'a, M: DesignMatrix> {
    /// Zero-copy view of the surviving columns of the full design matrix.
    pub x: ScreenedView<'a, M>,
    /// Group structure over surviving features (groups that lost all
    /// features to (L₂) are dropped entirely).
    pub groups: GroupStructure,
    /// For each reduced group, its index in the original group structure.
    /// Lets the runner project per-group quantities cached on the full
    /// matrix (e.g. the BCD Lipschitz constants `‖X_g‖₂²`) onto the
    /// reduced problem without recomputation.
    pub group_map: Vec<usize>,
}

impl<'a, M: DesignMatrix> ReducedProblem<'a, M> {
    /// Build from a screening outcome. Returns `None` when nothing
    /// survives (the solution is identically zero).
    ///
    /// The reduced groups carry the **original** penalty weights `√n_g`:
    /// screened features are certified zero at the optimum, so the group
    /// norm over the survivors equals the norm over the full group — the
    /// reduced problem with original weights is *exactly* the restricted
    /// full problem. Recomputing `√(kept)` would silently under-penalize.
    pub fn build(
        x: &'a M,
        groups: &GroupStructure,
        out: &TlfreOutcome,
    ) -> Option<ReducedProblem<'a, M>> {
        let mut sizes = Vec::new();
        let mut weights = Vec::new();
        let mut feature_map = Vec::new();
        let mut group_map = Vec::new();
        for (g, s, e) in groups.iter() {
            if !out.group_kept[g] {
                continue;
            }
            let before = feature_map.len();
            for i in s..e {
                if out.feature_kept[i] {
                    feature_map.push(i);
                }
            }
            let kept = feature_map.len() - before;
            if kept > 0 {
                sizes.push(kept);
                weights.push(groups.weight(g));
                group_map.push(g);
            }
        }
        if feature_map.is_empty() {
            return None;
        }
        Some(ReducedProblem {
            x: ScreenedView::new(x, feature_map),
            groups: GroupStructure::from_sizes_weighted(&sizes, &weights),
            group_map,
        })
    }

    /// For each reduced column, its index in the full feature space.
    #[inline]
    pub fn feature_map(&self) -> &[usize] {
        self.x.col_map()
    }

    /// Restrict a full coefficient vector to the reduced space (warm start).
    pub fn gather(&self, full: &[f32]) -> Vec<f32> {
        self.feature_map().iter().map(|&j| full[j]).collect()
    }

    /// Scatter a reduced solution into a zeroed full-space vector.
    pub fn scatter(&self, reduced: &[f32], full_out: &mut [f32]) {
        assert_eq!(reduced.len(), self.feature_map().len());
        full_out.fill(0.0);
        for (k, &j) in self.feature_map().iter().enumerate() {
            full_out[j] = reduced[k];
        }
    }

    #[inline]
    pub fn n_features(&self) -> usize {
        self.feature_map().len()
    }

    /// Materialize the reduced design as a gathered dense copy (the seed
    /// behaviour; kept behind `PathConfig::materialize_reduced` and for the
    /// view-vs-copy equivalence tests).
    pub fn materialize(&self) -> DenseMatrix {
        self.x.to_dense()
    }

    /// Project the path-level screening context onto this reduced problem
    /// for the in-solver dynamic GAP screen: exact per-column norms (the
    /// columns are shared with `X`) and the full-matrix per-group spectral
    /// norms as conservative upper bounds (`σmax(X_g[:,S]) ≤ σmax(X_g)` —
    /// a larger group ball only weakens, never unsafes, the sphere test).
    /// Returns `(col_norms, group_spectral)` in reduced index order.
    pub fn project_screen_context(
        &self,
        ctx: &crate::screening::tlfre::TlfreContext,
    ) -> (Vec<f64>, Vec<f64>) {
        let col_norms = self.feature_map().iter().map(|&j| ctx.col_norms[j]).collect();
        let group_spectral =
            self.group_map.iter().map(|&g| ctx.group_spectral[g]).collect();
        (col_norms, group_spectral)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::screening::tlfre::{ScreenStats, TlfreOutcome};

    fn outcome(group_kept: Vec<bool>, feature_kept: Vec<bool>) -> TlfreOutcome {
        TlfreOutcome { group_kept, feature_kept, stats: ScreenStats::default() }
    }

    #[test]
    fn build_gather_scatter_roundtrip() {
        let x = DenseMatrix::from_fn(3, 6, |i, j| (i * 6 + j) as f32);
        let groups = GroupStructure::from_sizes(&[2, 2, 2]);
        // Reject group 1 entirely; reject feature 5 inside group 2.
        let out = outcome(
            vec![true, false, true],
            vec![true, true, false, false, true, false],
        );
        let red = ReducedProblem::build(&x, &groups, &out).unwrap();
        assert_eq!(red.feature_map(), &[0, 1, 4]);
        assert_eq!(red.group_map, vec![0, 2]);
        assert_eq!(red.groups.n_groups(), 2);
        assert_eq!(red.groups.size(0), 2);
        assert_eq!(red.groups.size(1), 1);
        // Reduced column 2 is full column 4 — zero-copy, so compare through
        // the materialized view.
        assert_eq!(red.materialize().col(2), x.col(4));

        let full = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let g = red.gather(&full);
        assert_eq!(g, vec![1.0, 2.0, 5.0]);
        let mut back = vec![9.0f32; 6];
        red.scatter(&g, &mut back);
        assert_eq!(back, vec![1.0, 2.0, 0.0, 0.0, 5.0, 0.0]);
    }

    #[test]
    fn group_emptied_by_l2_is_dropped() {
        let x = DenseMatrix::from_fn(2, 4, |_, j| j as f32 + 1.0);
        let groups = GroupStructure::from_sizes(&[2, 2]);
        // group 0 kept by L1 but both features rejected by L2
        let out = outcome(vec![true, true], vec![false, false, true, true]);
        let red = ReducedProblem::build(&x, &groups, &out).unwrap();
        assert_eq!(red.groups.n_groups(), 1);
        assert_eq!(red.feature_map(), &[2, 3]);
        assert_eq!(red.group_map, vec![1], "emptied group must not appear in group_map");
    }

    #[test]
    fn nothing_survives_returns_none() {
        let x = DenseMatrix::from_fn(2, 4, |_, j| j as f32);
        let groups = GroupStructure::from_sizes(&[2, 2]);
        let out = outcome(vec![false, false], vec![false; 4]);
        assert!(ReducedProblem::build(&x, &groups, &out).is_none());
    }

    #[test]
    fn builds_over_csc_backend() {
        let xd = DenseMatrix::from_fn(3, 4, |i, j| ((i + j) % 2) as f32);
        let xs = crate::linalg::CscMatrix::from_dense(&xd);
        let groups = GroupStructure::from_sizes(&[2, 2]);
        let out = outcome(vec![true, false], vec![true, true, false, false]);
        let red = ReducedProblem::build(&xs, &groups, &out).unwrap();
        assert_eq!(red.n_features(), 2);
        assert_eq!(red.materialize().col(0), xd.col(0));
    }
}
