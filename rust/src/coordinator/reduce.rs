//! Reduced-problem extraction and solution scatter.
//!
//! After a TLFre screening pass, the solver only sees the surviving
//! features: a column-gathered copy of `X` (contiguous, cache-friendly)
//! and a recomputed group structure over the survivors. Solutions are
//! scattered back into the full coefficient vector — screened positions
//! are exactly zero by the safety guarantee.

use crate::groups::GroupStructure;
use crate::linalg::DenseMatrix;
use crate::screening::tlfre::TlfreOutcome;

/// A reduced SGL problem, with the bookkeeping to go back to full space.
#[derive(Debug, Clone)]
pub struct ReducedProblem {
    /// Gathered design matrix over surviving features.
    pub x: DenseMatrix,
    /// Group structure over surviving features (groups that lost all
    /// features to (L₂) are dropped entirely).
    pub groups: GroupStructure,
    /// For each reduced column, its index in the full feature space.
    pub feature_map: Vec<usize>,
}

impl ReducedProblem {
    /// Build from a screening outcome. Returns `None` when nothing
    /// survives (the solution is identically zero).
    ///
    /// The reduced groups carry the **original** penalty weights `√n_g`:
    /// screened features are certified zero at the optimum, so the group
    /// norm over the survivors equals the norm over the full group — the
    /// reduced problem with original weights is *exactly* the restricted
    /// full problem. Recomputing `√(kept)` would silently under-penalize.
    pub fn build(x: &DenseMatrix, groups: &GroupStructure, out: &TlfreOutcome) -> Option<ReducedProblem> {
        let mut sizes = Vec::new();
        let mut weights = Vec::new();
        let mut feature_map = Vec::new();
        for (g, s, e) in groups.iter() {
            if !out.group_kept[g] {
                continue;
            }
            let before = feature_map.len();
            for i in s..e {
                if out.feature_kept[i] {
                    feature_map.push(i);
                }
            }
            let kept = feature_map.len() - before;
            if kept > 0 {
                sizes.push(kept);
                weights.push(groups.weight(g));
            }
        }
        if feature_map.is_empty() {
            return None;
        }
        Some(ReducedProblem {
            x: x.select_cols(&feature_map),
            groups: GroupStructure::from_sizes_weighted(&sizes, &weights),
            feature_map,
        })
    }

    /// Restrict a full coefficient vector to the reduced space (warm start).
    pub fn gather(&self, full: &[f32]) -> Vec<f32> {
        self.feature_map.iter().map(|&j| full[j]).collect()
    }

    /// Scatter a reduced solution into a zeroed full-space vector.
    pub fn scatter(&self, reduced: &[f32], full_out: &mut [f32]) {
        assert_eq!(reduced.len(), self.feature_map.len());
        full_out.fill(0.0);
        for (k, &j) in self.feature_map.iter().enumerate() {
            full_out[j] = reduced[k];
        }
    }

    #[inline]
    pub fn n_features(&self) -> usize {
        self.feature_map.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::screening::tlfre::{ScreenStats, TlfreOutcome};

    fn outcome(group_kept: Vec<bool>, feature_kept: Vec<bool>) -> TlfreOutcome {
        TlfreOutcome { group_kept, feature_kept, stats: ScreenStats::default() }
    }

    #[test]
    fn build_gather_scatter_roundtrip() {
        let x = DenseMatrix::from_fn(3, 6, |i, j| (i * 6 + j) as f32);
        let groups = GroupStructure::from_sizes(&[2, 2, 2]);
        // Reject group 1 entirely; reject feature 5 inside group 2.
        let out = outcome(
            vec![true, false, true],
            vec![true, true, false, false, true, false],
        );
        let red = ReducedProblem::build(&x, &groups, &out).unwrap();
        assert_eq!(red.feature_map, vec![0, 1, 4]);
        assert_eq!(red.groups.n_groups(), 2);
        assert_eq!(red.groups.size(0), 2);
        assert_eq!(red.groups.size(1), 1);
        assert_eq!(red.x.col(2), x.col(4));

        let full = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let g = red.gather(&full);
        assert_eq!(g, vec![1.0, 2.0, 5.0]);
        let mut back = vec![9.0f32; 6];
        red.scatter(&g, &mut back);
        assert_eq!(back, vec![1.0, 2.0, 0.0, 0.0, 5.0, 0.0]);
    }

    #[test]
    fn group_emptied_by_l2_is_dropped() {
        let x = DenseMatrix::from_fn(2, 4, |_, j| j as f32 + 1.0);
        let groups = GroupStructure::from_sizes(&[2, 2]);
        // group 0 kept by L1 but both features rejected by L2
        let out = outcome(vec![true, true], vec![false, false, true, true]);
        let red = ReducedProblem::build(&x, &groups, &out).unwrap();
        assert_eq!(red.groups.n_groups(), 1);
        assert_eq!(red.feature_map, vec![2, 3]);
    }

    #[test]
    fn nothing_survives_returns_none() {
        let x = DenseMatrix::from_fn(2, 4, |_, j| j as f32);
        let groups = GroupStructure::from_sizes(&[2, 2]);
        let out = outcome(vec![false, false], vec![false; 4]);
        assert!(ReducedProblem::build(&x, &groups, &out).is_none());
    }
}
