//! The streaming path driver: **one** per-λ loop, many consumers.
//!
//! Every pathwise workload in this crate — the TLFre runner, the
//! no-screening baseline, the DPC/nonnegative-Lasso runners, and
//! cross-validation — walks the same descending log-λ grid with the same
//! interlock per step: screen → reduce → refresh spectral bounds →
//! dispatch the configured solver → scatter the solution back to full
//! space. Before this module existed, `cv::path_coefficients` hand-mirrored
//! that loop and drifted (it hardcoded FISTA while the runner dispatched on
//! [`SolverKind`]); now there is exactly one copy of the loop, and
//! consumers differ only in the [`PathSink`] they attach.
//!
//! ## Architecture
//!
//! * A **path engine** (crate-internal `PathEngine`) owns the per-family
//!   step: `TlfreEngine` and `BaselineEngine` for SGL, `DpcEngine`
//!   and `DpcBaselineEngine` for the nonnegative Lasso. Engines hold the
//!   per-path state — warm-started β, screening context, the once-per-path
//!   `SpectralCache` and the amortized refreshers — so a path is a fold
//!   over `engine.step(λ, λ̄)`.
//! * The **driver** (`drive`, via the public `drive_*` wrappers) owns the
//!   grid loop and the screen/solve time totals, and streams every step to
//!   a caller-supplied sink.
//! * A **[`PathSink`]** receives `(step record, current full-space β)` per
//!   grid point. [`StepSink`] collects the per-λ statistics (the classic
//!   `run_*_path` outputs), [`CoefficientSink`] collects a dense β per λ
//!   (`cv::path_coefficients`), and [`HoldoutSink`] folds β into held-out
//!   predictions on the spot (cross-validation) — each fold×α grid is
//!   walked **once**, there is no second coefficient pass.
//!
//! ## The sink contract
//!
//! `on_step` is called exactly once per grid point, in descending-λ order,
//! starting with the λmax point (where β ≡ 0 by construction). The β slice
//! is the engine's live full-space coefficient vector: valid for the
//! duration of the call, owned copies must be made to keep it. Sinks must
//! not assume anything about timing — screen/solve seconds in the step
//! records are measured around the engine's own work and exclude sink
//! time, so an expensive sink (e.g. held-out prediction) never pollutes
//! the screening-vs-solving accounting that the paper's tables report.
//!
//! Determinism: engines call only worker-count-invariant kernels (see
//! `linalg/README.md`), so for a fixed input the streamed steps and β are
//! bitwise identical at every `TLFRE_THREADS` — this is what makes the
//! fold-parallel CV in [`super::cv`] bitwise reproducible.
//!
//! ## Screening pipelines
//!
//! Since the composable-screening refactor the SGL engine does not call a
//! specific rule: it runs the [`ScreenPipeline`] named by
//! `PathConfig::screen` (`tlfre` by default — the paper's protocol —,
//! `tlfre+gap`, `gap`, `strong+kkt`, `none`), with three structural
//! guarantees owned *here* rather than by each rule:
//!
//! * every rule in a step shares one dual preamble (residual, correlation
//!   sweep; the feasibility-scaled θ̄ and its gap only when a rule
//!   declares `needs_previous_dual`) — composing rules adds no matvec;
//! * any pipeline containing a [`crate::screening::rule::Safety::Heuristic`]
//!   rule runs the KKT recovery loop after each solve: violated discarded
//!   coordinates are re-admitted and the reduced problem re-solved (KKT
//!   check time charged to screening, re-solves to solving);
//! * GAP pipelines attach a [`GapSafeDynamic`] state to each reduced
//!   solve, so the solver itself keeps shrinking the problem at gap-check
//!   cadence; per-step eviction counts land in `PathStep::dynamic_evicted`.

use super::dpc_runner::{DpcPathConfig, DpcStep};
use super::path::log_lambda_grid;
use super::reduce::ReducedProblem;
use super::refresh::{GroupRefresher, ScalarRefresher};
use super::runner::{PathConfig, PathStep, SolverKind};
use crate::groups::GroupStructure;
use crate::linalg::ops;
use crate::linalg::{DesignMatrix, ScreenedView};
use crate::nonneg::{
    lambda_max as nonneg_lambda_max, nonneg_lipschitz, solve_nonneg, NonnegOptions, NonnegProblem,
};
use crate::screening::gap_safe::{GapSafeDynamic, GapSafeDynamicNonneg};
use crate::screening::lambda_max::{sgl_lambda_max, LambdaMaxInfo};
use crate::screening::rule::{stats_from_masks, ScreenInput, ScreenPipeline, SurvivorMask};
use crate::screening::strong_rule::kkt_violations_with_resid;
use crate::screening::tlfre::{ScreenStats, TlfreContext, TlfreOutcome};
use crate::sgl::bcd::{bcd_group_lipschitz, solve_bcd, BcdOptions};
use crate::sgl::fista::{lipschitz, lipschitz_of, solve_fista, FistaOptions};
use crate::sgl::problem::{SglParams, SglProblem};
use crate::sgl::GroupColoring;
use crate::util::Timer;
use std::cell::RefCell;

/// Receiver of a streamed path walk (see the module docs for the exact
/// call contract). `Step` is [`PathStep`] for SGL paths and [`DpcStep`]
/// for nonnegative-Lasso paths.
pub trait PathSink<Step> {
    /// Called once, before any step, with λmax and the resolved λ grid.
    fn on_grid(&mut self, _lambda_max: f64, _grid: &[f64]) {}

    /// Called once per grid point (descending λ, λmax first) with the step
    /// record and the engine's current full-space coefficient vector.
    fn on_step(&mut self, step: &Step, beta: &[f32]);
}

/// Whole-path totals returned by every `drive_*` entry point.
#[derive(Debug, Clone, Copy)]
pub struct PathTotals {
    pub lambda_max: f64,
    /// Total screening time, including the one-off spectral preamble.
    pub screen_total_s: f64,
    /// Total solver time.
    pub solve_total_s: f64,
    /// True when the engine's wall-clock budget
    /// ([`super::SolveControls::max_seconds`]) stopped the grid walk before the last
    /// grid point: the sink saw a clean completed prefix of the path and
    /// nothing half-done.
    pub truncated: bool,
}

/// One engine step: the family-specific record plus its timings.
pub(crate) struct EngineStep<S> {
    pub step: S,
    pub screen_s: f64,
    pub solve_s: f64,
}

/// A path family: owns the per-λ state and produces one step per grid
/// point. Implementations keep β warm-started across steps.
pub(crate) trait PathEngine {
    type Step;

    /// λmax of this path (grid anchor).
    fn lambda_max(&self) -> f64;

    /// `(lambda_min_ratio, n_lambda)` for grid construction.
    fn grid_shape(&self) -> (f64, usize);

    /// Seconds spent in the constructor's screening/spectral preamble
    /// (charged to the path's screening total).
    fn preamble_s(&self) -> f64;

    /// The λmax step record (exact zero solution, zero cost).
    fn zero_step(&self, lambda: f64) -> Self::Step;

    /// The current full-space coefficient vector.
    fn beta(&self) -> &[f32];

    /// Advance from λ̄ to λ: screen, reduce, solve, scatter.
    fn step(&mut self, lambda: f64, lambda_bar: f64) -> EngineStep<Self::Step>;

    /// Path-level wall-clock deadline, derived once at engine construction
    /// from the config's budget. The driver refuses to *start* a step past
    /// it (the completed prefix is returned with
    /// [`PathTotals::truncated`]); engines additionally hand the same
    /// deadline to their solvers so a single over-budget solve degrades to
    /// best-so-far instead of running long. `None` (the default) disables
    /// both checks.
    fn deadline(&self) -> Option<std::time::Instant> {
        None
    }
}

/// The single per-λ loop. Streams every step to `sink` and accumulates the
/// screen/solve totals; sink time is outside both timers by construction.
pub(crate) fn drive<E: PathEngine, K: PathSink<E::Step>>(
    engine: E,
    sink: &mut K,
) -> PathTotals {
    drive_prefix(engine, sink, None)
}

/// [`drive`] that stops after `stop_after` grid points (counting the λmax
/// zero step), returning the clean completed prefix with
/// [`PathTotals::truncated`] set when the cut fired before the grid end.
/// `None` walks the full grid. The serve engine's `solve-point` prefix
/// solver: a prefix of `drive`'s walk is bitwise identical to the same
/// prefix of the full walk because the loop body is literally the same
/// code over the same grid.
pub(crate) fn drive_prefix<E: PathEngine, K: PathSink<E::Step>>(
    mut engine: E,
    sink: &mut K,
    stop_after: Option<usize>,
) -> PathTotals {
    let lambda_max = engine.lambda_max();
    let (min_ratio, n_lambda) = engine.grid_shape();
    let grid = log_lambda_grid(lambda_max, min_ratio, n_lambda);
    sink.on_grid(lambda_max, &grid);
    let first = engine.zero_step(grid[0]);
    sink.on_step(&first, engine.beta());
    let mut screen_total = engine.preamble_s();
    let mut solve_total = 0.0f64;
    let mut lambda_bar = grid[0];
    let deadline = engine.deadline();
    let mut truncated = false;
    let mut done = 1usize;
    for &lambda in &grid[1..] {
        if stop_after.is_some_and(|cap| done >= cap) {
            truncated = true;
            break;
        }
        // Budget check *between* steps: a step either runs to its own
        // (budget-degraded) completion or does not start, so the sink only
        // ever sees finished records.
        if crate::sgl::fista::deadline_passed(deadline) {
            truncated = true;
            break;
        }
        let es = engine.step(lambda, lambda_bar);
        screen_total += es.screen_s;
        solve_total += es.solve_s;
        sink.on_step(&es.step, engine.beta());
        lambda_bar = lambda;
        done += 1;
    }
    PathTotals { lambda_max, screen_total_s: screen_total, solve_total_s: solve_total, truncated }
}

// ---------------------------------------------------------------------------
// Sinks
// ---------------------------------------------------------------------------

/// Collects every step record — the sink behind `run_tlfre_path`,
/// `run_baseline_path`, `run_dpc_path` and `run_nonneg_baseline`.
#[derive(Debug)]
pub struct StepSink<Step> {
    pub steps: Vec<Step>,
}

impl<Step> StepSink<Step> {
    pub fn new() -> StepSink<Step> {
        StepSink { steps: Vec::new() }
    }
}

impl<Step> Default for StepSink<Step> {
    fn default() -> Self {
        Self::new()
    }
}

impl<Step: Clone> PathSink<Step> for StepSink<Step> {
    fn on_grid(&mut self, _lambda_max: f64, grid: &[f64]) {
        self.steps.reserve(grid.len());
    }

    fn on_step(&mut self, step: &Step, _beta: &[f32]) {
        self.steps.push(step.clone());
    }
}

/// Collects a dense coefficient vector per λ — the sink behind
/// `cv::path_coefficients` and the coefficient-level A/B tests.
#[derive(Debug, Default)]
pub struct CoefficientSink {
    pub betas: Vec<Vec<f32>>,
}

impl CoefficientSink {
    pub fn new() -> CoefficientSink {
        CoefficientSink { betas: Vec::new() }
    }
}

impl<Step> PathSink<Step> for CoefficientSink {
    fn on_grid(&mut self, _lambda_max: f64, grid: &[f64]) {
        self.betas.reserve(grid.len());
    }

    fn on_step(&mut self, _step: &Step, beta: &[f32]) {
        self.betas.push(beta.to_vec());
    }
}

/// Folds each step's β into held-out predictions on the spot — the
/// cross-validation sink. Per grid point it records the held-out MSE and
/// the nonzero count, so CV needs no second coefficient walk (and no
/// per-step β storage at all).
#[derive(Debug)]
pub struct HoldoutSink<'a, M: DesignMatrix> {
    x_test: &'a M,
    y_test: &'a [f32],
    pred: Vec<f32>,
    /// Held-out mean squared error per grid point.
    pub mse: Vec<f64>,
    /// Nonzero coefficient count per grid point (as f64 for fold
    /// averaging).
    pub nnz: Vec<f64>,
}

impl<'a, M: DesignMatrix> HoldoutSink<'a, M> {
    pub fn new(x_test: &'a M, y_test: &'a [f32]) -> HoldoutSink<'a, M> {
        assert_eq!(x_test.rows(), y_test.len(), "held-out X rows must match y length");
        HoldoutSink {
            x_test,
            y_test,
            pred: vec![0.0; y_test.len()],
            mse: Vec::new(),
            nnz: Vec::new(),
        }
    }
}

impl<Step, M: DesignMatrix> PathSink<Step> for HoldoutSink<'_, M> {
    fn on_grid(&mut self, _lambda_max: f64, grid: &[f64]) {
        self.mse.reserve(grid.len());
        self.nnz.reserve(grid.len());
    }

    fn on_step(&mut self, _step: &Step, beta: &[f32]) {
        self.x_test.matvec(beta, &mut self.pred);
        let mut e = 0.0f64;
        for (p, t) in self.pred.iter().zip(self.y_test) {
            let d = (p - t) as f64;
            e += d * d;
        }
        self.mse.push(e / self.y_test.len() as f64);
        self.nnz.push((beta.len() - ops::count_zeros(beta)) as f64);
    }
}

// ---------------------------------------------------------------------------
// The path-level spectral cache (shared by the SGL engines)
// ---------------------------------------------------------------------------

/// Lipschitz data computed **once** per path from the full matrix and
/// reused (as valid upper bounds, `σmax(X[:,S]) ≤ σmax(X)`) for every
/// screened subproblem — by default no power iteration runs inside the
/// per-λ loop. Its construction cost is counted as screening time, exactly
/// like the paper's one-off `‖X_g‖₂` power-method accounting.
pub(crate) struct SpectralCache {
    /// `‖X‖₂²·1.02²` — the FISTA step bound (see [`lipschitz`]).
    pub(crate) lip: Option<f64>,
    /// Per-group `‖X_g‖₂²` in original group order — the BCD step bounds.
    pub(crate) group_l: Option<Vec<f64>>,
    /// Red-black group coloring for pool-parallel BCD sweeps, computed
    /// once per path from the full matrix's storage pattern and projected
    /// per reduced problem (reduced supports are subsets, so full-matrix
    /// classes stay conflict-free on every survivor view).
    pub(crate) coloring: Option<GroupColoring>,
}

impl SpectralCache {
    /// Build for a TLFre path run. Each solver only pays for the constants
    /// it uses: FISTA the full-matrix `‖X‖₂²` ([`lipschitz`]'s recipe), BCD
    /// the per-group `‖X_g‖₂²` via [`bcd_group_lipschitz`] — the solver's
    /// own recipe, so the cached constants are identical to what
    /// `solve_bcd` would self-compute for the full problem (and what
    /// `run_baseline_path` supplies). The BCD coloring rides along when
    /// `cfg.parallel_bcd_groups` asks for it (orthogonal to the Lipschitz
    /// mode, so it is cached even under `exact_view_lipschitz`).
    pub(crate) fn for_path<M: DesignMatrix>(
        prob: &SglProblem<'_, M>,
        cfg: &PathConfig,
    ) -> SpectralCache {
        let coloring = match cfg.solver {
            SolverKind::Bcd if cfg.parallel_bcd_groups => {
                Some(GroupColoring::compute(prob.x, prob.groups))
            }
            _ => None,
        };
        if cfg.exact_view_lipschitz {
            return SpectralCache { lip: None, group_l: None, coloring };
        }
        match cfg.solver {
            SolverKind::Fista => {
                SpectralCache { lip: Some(lipschitz(prob)), group_l: None, coloring }
            }
            SolverKind::Bcd => SpectralCache {
                lip: None,
                group_l: Some(bcd_group_lipschitz(prob.x, &prob.groups.ranges())),
                coloring,
            },
        }
    }

    /// Project the per-group constants onto a reduced problem's groups.
    pub(crate) fn reduced_group_l<M: DesignMatrix>(
        &self,
        red: &ReducedProblem<'_, M>,
    ) -> Option<Vec<f64>> {
        self.group_l.as_ref().map(|gl| red.group_map.iter().map(|&g| gl[g]).collect())
    }

    /// Project the coloring onto a reduced problem's groups.
    pub(crate) fn reduced_coloring<M: DesignMatrix>(
        &self,
        red: &ReducedProblem<'_, M>,
    ) -> Option<GroupColoring> {
        self.coloring.as_ref().map(|c| c.project(&red.group_map))
    }
}

/// Dispatch one reduced (or full) solve on [`PathConfig::solver`]. The
/// **single** solver match shared by every path walker — a new
/// [`SolverKind`] cannot be wired into one walker and forgotten in
/// another.
#[allow(clippy::too_many_arguments)]
pub(crate) fn solve<M: DesignMatrix>(
    prob: &SglProblem<'_, M>,
    params: &SglParams,
    warm: Option<&[f32]>,
    cfg: &PathConfig,
    tol: f64,
    lip: Option<f64>,
    group_lip: Option<&[f64]>,
    coloring: Option<&GroupColoring>,
    dynamic: Option<&RefCell<GapSafeDynamic>>,
    deadline: Option<std::time::Instant>,
) -> crate::sgl::fista::SolveResult {
    match cfg.solver {
        SolverKind::Fista => solve_fista(
            prob,
            params,
            warm,
            &FistaOptions {
                tol,
                max_iter: cfg.max_iter,
                lipschitz: lip,
                dynamic_screen: dynamic,
                deadline,
                ..Default::default()
            },
        ),
        SolverKind::Bcd => solve_bcd(
            prob,
            params,
            warm,
            &BcdOptions {
                tol,
                max_sweeps: cfg.max_iter,
                group_lipschitz: group_lip,
                parallel_groups: cfg.parallel_bcd_groups,
                coloring,
                dynamic_screen: dynamic,
                deadline,
                ..Default::default()
            },
        ),
    }
}

// ---------------------------------------------------------------------------
// SGL engines
// ---------------------------------------------------------------------------

/// Upper bound on KKT recovery rounds for heuristic pipelines (matches
/// `strong_rule::solve_with_strong_rule`'s historical cap). Working-set
/// pipelines use `SolveControls::ws_max_rounds` instead (plus slack for
/// the tight finish).
const MAX_KKT_ROUNDS: usize = 16;

/// Inner-tolerance relaxation for the working-set outer loop's *grow*
/// rounds: while the set may still be wrong, solving it tighter than
/// `WS_LOOSE_FACTOR × tol` is wasted work — the loose solution is only
/// used to probe full-problem KKT and pick the next growth step. The one
/// final solve after a clean KKT check runs at the target tolerance, so
/// the exactness contract is untouched.
const WS_LOOSE_FACTOR: f64 = 100.0;

/// Resolve a `PathConfig::max_seconds` budget into a wall-clock deadline,
/// anchored at engine construction (so screening preamble time counts
/// against the budget too).
fn path_deadline(max_seconds: Option<f64>) -> Option<std::time::Instant> {
    max_seconds.map(|s| std::time::Instant::now() + std::time::Duration::from_secs_f64(s))
}

/// The screened SGL path engine (the paper's Section 6.1 protocol),
/// parameterized by a composable [`ScreenPipeline`]. The default pipeline
/// ([`crate::screening::rule::ScreenKind::Tlfre`]) reproduces the paper's
/// exact two-layer protocol; GAP pipelines additionally shrink the live
/// problem *inside* the solver, and heuristic pipelines run behind the
/// KKT recovery loop in [`PathEngine::step`].
pub(crate) struct TlfreEngine<'a, M: DesignMatrix> {
    x: &'a M,
    y: &'a [f32],
    groups: &'a GroupStructure,
    cfg: &'a PathConfig,
    prob: SglProblem<'a, M>,
    ctx: TlfreContext,
    lmax: LambdaMaxInfo,
    spectral: SpectralCache,
    pipeline: ScreenPipeline<M>,
    scalar_refresh: Option<ScalarRefresher>,
    group_refresh: Option<GroupRefresher>,
    beta: Vec<f32>,
    resid: Vec<f32>,
    corr: Vec<f32>,
    preamble_s: f64,
    /// Wall-clock deadline from `cfg.max_seconds`, fixed at construction.
    deadline: Option<std::time::Instant>,
}

impl<'a, M: DesignMatrix> TlfreEngine<'a, M> {
    pub(crate) fn new(
        x: &'a M,
        y: &'a [f32],
        groups: &'a GroupStructure,
        cfg: &'a PathConfig,
    ) -> TlfreEngine<'a, M> {
        Self::with_pipeline(x, y, groups, cfg, ScreenPipeline::for_kind(cfg.screen))
    }

    /// Build with an explicit (possibly custom) pipeline — the seam behind
    /// [`drive_tlfre_path_with_pipeline`].
    pub(crate) fn with_pipeline(
        x: &'a M,
        y: &'a [f32],
        groups: &'a GroupStructure,
        cfg: &'a PathConfig,
        pipeline: ScreenPipeline<M>,
    ) -> TlfreEngine<'a, M> {
        cfg.validate();
        let prob = SglProblem::new(x, y, groups);
        let p = prob.n_features();
        let n = prob.n_samples();
        // Screening-side precomputation (counted as screening time, like
        // the paper's ‖X_g‖₂ power-method accounting). The spectral cache
        // lives here too: after this block the per-λ loop runs zero power
        // iterations unless `cfg.exact_view_lipschitz` opts back into
        // per-view estimates.
        let t = Timer::start();
        let ctx = TlfreContext::precompute(&prob);
        let lmax = sgl_lambda_max(&prob, cfg.alpha);
        let spectral = SpectralCache::for_path(&prob, cfg);
        let preamble_s = t.elapsed_s();
        // Amortized per-view Lipschitz refresh trackers (subset-validity
        // rule in `coordinator::refresh`); the exact mode supersedes them.
        let refresh_every =
            if cfg.exact_view_lipschitz { None } else { cfg.lipschitz_refresh_every };
        let scalar_refresh = match (refresh_every, cfg.solver) {
            (Some(k), SolverKind::Fista) => Some(ScalarRefresher::new(k, p)),
            _ => None,
        };
        let group_refresh = match (refresh_every, cfg.solver) {
            (Some(k), SolverKind::Bcd) => Some(GroupRefresher::new(k, p, groups.n_groups())),
            _ => None,
        };
        TlfreEngine {
            x,
            y,
            groups,
            cfg,
            prob,
            ctx,
            lmax,
            spectral,
            pipeline,
            scalar_refresh,
            group_refresh,
            beta: vec![0.0; p],
            resid: vec![0.0; n],
            corr: vec![0.0; p],
            preamble_s,
            deadline: path_deadline(cfg.max_seconds),
        }
    }

    /// Survivor mask that keeps everything — the `none` pipeline's
    /// "outcome" (the solver then sees the full problem through the same
    /// reduced-problem plumbing).
    fn keep_all(&self) -> TlfreOutcome {
        TlfreOutcome {
            group_kept: vec![true; self.prob.n_groups()],
            feature_kept: vec![true; self.prob.n_features()],
            stats: ScreenStats::default(),
        }
    }
}

impl<M: DesignMatrix> PathEngine for TlfreEngine<'_, M> {
    type Step = PathStep;

    fn lambda_max(&self) -> f64 {
        self.lmax.lambda_max
    }

    fn grid_shape(&self) -> (f64, usize) {
        (self.cfg.lambda_min_ratio, self.cfg.n_lambda)
    }

    fn preamble_s(&self) -> f64 {
        self.preamble_s
    }

    fn zero_step(&self, lambda: f64) -> PathStep {
        // At λmax the rejection is the λmax theorem's, not any rule's —
        // but an *empty* pipeline performs no screening at all and must
        // report none (its λmax step is a full-problem solve of β ≡ 0).
        let screened = !self.pipeline.is_empty();
        let p = self.prob.n_features();
        PathStep {
            lambda,
            r1: if screened { 1.0 } else { 0.0 },
            r2: 0.0,
            screen_s: 0.0,
            solve_s: 0.0,
            active_features: if screened { 0 } else { p },
            iters: 0,
            gap: 0.0,
            zeros: p,
            nonzeros: 0,
            groups_rejected: if screened { self.prob.n_groups() } else { 0 },
            features_rejected: 0,
            layers: Vec::new(),
            dynamic_evicted: 0,
            kkt_readmitted: 0,
            budget_exhausted: false,
            certified_suboptimality: 0.0,
            ws_rounds: 0,
            ws_final_size: 0,
        }
    }

    fn beta(&self) -> &[f32] {
        &self.beta
    }

    fn deadline(&self) -> Option<std::time::Instant> {
        self.deadline
    }

    fn step(&mut self, lambda: f64, lambda_bar: f64) -> EngineStep<PathStep> {
        let cfg = self.cfg;
        let p = self.prob.n_features();
        // Static screening: the pipeline's rules share one dual preamble —
        // θ̄ is the *feasibility-scaled* residual s·(y − Xβ̄)/λ̄ (guaranteed
        // dual feasible even for an inexact β̄), with the TLFre radius
        // inflated by the √(2·gap) optimum-distance bound (see
        // `tlfre_screen_inexact`) and the GAP rule consuming the same
        // residual/correlation sweeps at the new λ.
        let ts = Timer::start();
        let (mut outcome, layers, safe_mask) = if self.pipeline.is_empty() {
            (self.keep_all(), Vec::new(), SurvivorMask::all_kept(self.groups))
        } else {
            crate::sgl::objective::residual(&self.prob, &self.beta, &mut self.resid);
            self.prob.x.matvec_t(&self.resid, &mut self.corr);
            // The previous-λ dual point (feasibility bisection + θ̄
            // allocation) is only paid when some rule declares it needs it
            // — a `gap`-only pipeline screens from the target-λ gap alone.
            let (gap_bar, theta_bar): (f64, Vec<f32>) =
                if self.pipeline.needs_previous_dual() {
                    let params_bar = SglParams::from_alpha_lambda(cfg.alpha, lambda_bar);
                    let (gap_bar_full, s_feas) = crate::sgl::dual::duality_gap(
                        &self.prob,
                        &params_bar,
                        &self.beta,
                        &self.resid,
                        &self.corr,
                    );
                    let theta: Vec<f32> = self
                        .resid
                        .iter()
                        .map(|&v| (v as f64 * s_feas / lambda_bar) as f32)
                        .collect();
                    (gap_bar_full * cfg.gap_inflation, theta)
                } else {
                    (0.0, Vec::new())
                };
            let input = ScreenInput {
                prob: &self.prob,
                alpha: cfg.alpha,
                lambda,
                lambda_bar,
                beta_bar: &self.beta,
                resid_bar: &self.resid,
                corr_bar: &self.corr,
                theta_bar: &theta_bar,
                gap_bar,
                lmax: &self.lmax,
                ctx: &self.ctx,
            };
            self.pipeline.screen_full(&input)
        };
        let mut reduced = ReducedProblem::build(self.x, self.groups, &outcome);
        // Amortized Lipschitz refresh runs inside the screening timer —
        // the refresh is spectral preamble work, exactly like the
        // once-per-path cache, so cached-vs-refreshed-vs-exact `solve_s`
        // comparisons stay apples-to-apples.
        let mut step_lip = self.spectral.lip;
        let mut step_group_l: Option<Vec<f64>> = None;
        if let Some(red) = &reduced {
            if let Some(rf) = &mut self.scalar_refresh {
                let full = self.spectral.lip.expect("cached bound exists in refresh mode");
                step_lip = Some(rf.step(red.feature_map(), full, || lipschitz_of(&red.x)));
            }
            step_group_l = match &mut self.group_refresh {
                Some(rf) => {
                    let full =
                        self.spectral.group_l.as_deref().expect("cached full-matrix bounds exist");
                    Some(rf.step(
                        red.feature_map(),
                        &red.groups.ranges(),
                        &red.group_map,
                        full,
                        || bcd_group_lipschitz(&red.x, &red.groups.ranges()),
                    ))
                }
                // Cached full-matrix Lipschitz data: σmax over a column
                // subset never exceeds σmax over the full matrix, so the
                // path-level constants are valid steps for every reduced
                // problem — no per-λ power iteration.
                None => self.spectral.reduced_group_l(red),
            };
        }
        let mut screen_s = ts.elapsed_s();

        let params = SglParams::from_alpha_lambda(cfg.alpha, lambda);
        // Solve, with the KKT recovery loop for heuristic pipelines:
        // violators among the discarded coordinates are re-admitted and the
        // (grown) reduced problem re-solved. Safe pipelines exit after one
        // round by construction. Re-solve rounds fall back to the
        // always-valid full-matrix step bounds — the refreshed survivor-set
        // bounds were measured before re-admission grew the problem.
        //
        // Working-set pipelines upgrade this into the celer-style
        // loose-then-tight outer loop: while the set may still be wrong,
        // each round solves at `WS_LOOSE_FACTOR × tol`; a KKT violation
        // re-admits the violators AND grows the set geometrically
        // (`cfg.ws_growth`); a clean KKT check at loose tolerance triggers
        // one final *tight* solve of the same (small) reduced problem —
        // the expensive full-accuracy solve happens exactly once. Past
        // `cfg.ws_max_rounds` the set is restored to the full safe
        // survivor mask and the loop degenerates to the plain recovery
        // behaviour, so the heuristic can never compromise exactness.
        let ws_mode = self.pipeline.has_working_set();
        // `tight` = this round solves at the target tolerance. Non-ws
        // heuristic pipelines (strong+kkt) always solve tight, exactly as
        // before.
        let mut tight = !ws_mode;
        let mut ws_fallback = false;
        let hard_cap = if ws_mode { cfg.ws_max_rounds + 2 } else { MAX_KKT_ROUNDS };
        let mut solve_s = 0.0f64;
        let mut kkt_readmitted = 0usize;
        let mut dynamic_evicted = 0usize;
        // Full-space indices of in-solver evictions, for verify_safety.
        let mut dyn_evicted_full: Vec<usize> = Vec::new();
        let mut rounds = 0usize;
        // Total solver iterations across recovery rounds — like solve_s,
        // re-solves count toward the step's reported work.
        let mut iters = 0usize;
        let (active, gap, budget_exhausted) = loop {
            rounds += 1;
            let round_tol = if tight { cfg.tol } else { cfg.tol * WS_LOOSE_FACTOR };
            let ts = Timer::start();
            // Per-round dynamic-eviction stats: merged into the step totals
            // only when the round's result is accepted (the loop breaks).
            // A round whose KKT check finds violations solved a mis-reduced
            // problem — its evictions certify nothing and are discarded.
            let mut round_dyn_evicted = 0usize;
            let mut round_dyn_ids: Vec<usize> = Vec::new();
            let round = match &reduced {
                None => {
                    self.beta.fill(0.0);
                    (0usize, 0usize, 0.0f64, false, self.y.to_vec())
                }
                Some(red) => {
                    let warm = red.gather(&self.beta);
                    let (round_lip, round_group_l) = if rounds == 1 {
                        (step_lip, step_group_l.clone())
                    } else {
                        (self.spectral.lip, self.spectral.reduced_group_l(red))
                    };
                    // Dynamic state attachment. Safe pipelines: the first
                    // (only) solve — a fresh state on KKT re-solve rounds
                    // would re-evict (and re-count) coordinates already
                    // evicted in round 1, and the sphere certifies zeros of
                    // the problem the solver is actually given, so a
                    // heuristically mis-reduced problem must not feed it.
                    // Working-set pipelines: tight rounds only — the final
                    // accepted round's reduction is KKT-certified as the
                    // full problem's optimum, making those evictions
                    // legitimate certificates; loose grow rounds never
                    // attach (see the round-stat discard above for tight
                    // rounds that fail the KKT check).
                    let attach_dyn = if ws_mode {
                        tight && self.pipeline.dynamic()
                    } else {
                        rounds == 1 && self.pipeline.dynamic() && self.pipeline.all_safe()
                    };
                    let dyn_state = if attach_dyn {
                        let (cn, gs) = red.project_screen_context(&self.ctx);
                        Some(RefCell::new(GapSafeDynamic::new(cfg.alpha, cn, gs)))
                    } else {
                        None
                    };
                    let res = if cfg.materialize_reduced {
                        // Seed behaviour: physical column gather per λ. The
                        // projected coloring is NOT handed down here: its
                        // conflict analysis saw the original backend's
                        // storage, and a dense gathered copy touches every
                        // row — the solver recomputes its own (trivially
                        // sequential) schedule instead.
                        let xd = red.materialize();
                        let rp = SglProblem::new(&xd, self.y, &red.groups);
                        solve(
                            &rp,
                            &params,
                            Some(&warm),
                            cfg,
                            round_tol,
                            round_lip,
                            round_group_l.as_deref(),
                            None,
                            dyn_state.as_ref(),
                            self.deadline,
                        )
                    } else {
                        // Zero-copy: the solver runs on the survivor view.
                        let red_coloring = self.spectral.reduced_coloring(red);
                        let rp = SglProblem::new(&red.x, self.y, &red.groups);
                        solve(
                            &rp,
                            &params,
                            Some(&warm),
                            cfg,
                            round_tol,
                            round_lip,
                            round_group_l.as_deref(),
                            red_coloring.as_ref(),
                            dyn_state.as_ref(),
                            self.deadline,
                        )
                    };
                    red.scatter(&res.beta, &mut self.beta);
                    if let Some(st) = dyn_state {
                        let st = st.into_inner();
                        round_dyn_evicted = st.evicted();
                        if cfg.verify_safety {
                            round_dyn_ids
                                .extend(st.evicted_ids().iter().map(|&k| red.feature_map()[k]));
                        }
                    }
                    (red.n_features(), res.iters, res.gap, res.budget_exhausted, res.resid)
                }
            };
            solve_s += ts.elapsed_s();
            iters += round.1;
            if self.pipeline.all_safe() || rounds > hard_cap {
                dynamic_evicted += round_dyn_evicted;
                dyn_evicted_full.extend(round_dyn_ids);
                break (round.0, round.2, round.3);
            }
            // Heuristic pipeline: check the discarded coordinates' KKT
            // conditions (a screening-correctness cost, charged to the
            // screening timer like the rest of the rule work). The solver's
            // own final residual is reused — the reduced residual equals
            // the full-space one (discarded coordinates are zero) — so the
            // check costs one matvec_t, not a residual + matvec_t.
            let tk = Timer::start();
            let bad =
                kkt_violations_with_resid(&self.prob, &params, &self.beta, &outcome, &round.4);
            screen_s += tk.elapsed_s();
            if bad.is_empty() {
                if tight {
                    dynamic_evicted += round_dyn_evicted;
                    dyn_evicted_full.extend(round_dyn_ids);
                    break (round.0, round.2, round.3);
                }
                // Loose working set is KKT-clean: re-solve the SAME reduced
                // problem (warm from its own loose solution) to the target
                // tolerance. This is the one full-accuracy solve.
                tight = true;
                continue;
            }
            kkt_readmitted += bad.len();
            for &i in &bad {
                outcome.feature_kept[i] = true;
                outcome.group_kept[self.groups.group_of(i)] = true;
            }
            if ws_mode && !ws_fallback {
                if rounds >= cfg.ws_max_rounds {
                    // Safe fallback: restore the full safe survivor set
                    // (union keeps the KKT re-admissions — a violator may
                    // be a safely-screened coordinate flagged at loose
                    // accuracy) and finish at target tolerance like a
                    // plain heuristic pipeline.
                    for (k, &s) in
                        outcome.group_kept.iter_mut().zip(&safe_mask.group_kept)
                    {
                        *k = *k || s;
                    }
                    for (k, &s) in
                        outcome.feature_kept.iter_mut().zip(&safe_mask.feature_kept)
                    {
                        *k = *k || s;
                    }
                    ws_fallback = true;
                    tight = true;
                } else {
                    // Grow the admitted set geometrically past the
                    // violators and keep probing at loose tolerance.
                    self.pipeline.grow(self.groups, &mut outcome, &safe_mask, cfg.ws_growth);
                    tight = false;
                }
            }
            reduced = ReducedProblem::build(self.x, self.groups, &outcome);
        };
        let ws_rounds = if ws_mode { rounds } else { 0 };
        let ws_final_size = if ws_mode { active } else { 0 };
        // Final-mask stats (post re-admission/growth) keep r₁/r₂ honest
        // for heuristic pipelines too.
        let stats = if kkt_readmitted > 0 || ws_mode {
            stats_from_masks(self.groups, &outcome.group_kept, &outcome.feature_kept)
        } else {
            outcome.stats.clone()
        };

        if cfg.verify_safety {
            // Independent full solve; every screened coordinate must be 0.
            // The cached constants are exact for the full problem.
            // No deadline on the verification solve: a budget-truncated
            // reference would turn the safety assertions into noise.
            let full = solve(
                &self.prob,
                &params,
                None,
                cfg,
                cfg.tol,
                self.spectral.lip,
                self.spectral.group_l.as_deref(),
                self.spectral.coloring.as_ref(),
                None,
                None,
            );
            for j in 0..p {
                if !outcome.feature_kept[j] {
                    assert!(
                        full.beta[j].abs() < 1e-4,
                        "SAFETY VIOLATION at λ={lambda}: feature {j} screened but β={}",
                        full.beta[j]
                    );
                }
            }
            // In-solver dynamic evictions are certificates too: every
            // coordinate the GAP sphere dropped mid-solve must be zero in
            // the independent full solve.
            for &j in &dyn_evicted_full {
                assert!(
                    full.beta[j].abs() < 1e-4,
                    "DYNAMIC SAFETY VIOLATION at λ={lambda}: feature {j} evicted in-solver \
                     but β={}",
                    full.beta[j]
                );
            }
        }

        let zeros = ops::count_zeros(&self.beta);
        let m = zeros.max(1);
        EngineStep {
            step: PathStep {
                lambda,
                r1: stats.features_in_rejected_groups as f64 / m as f64,
                r2: stats.features_rejected_l2 as f64 / m as f64,
                screen_s,
                solve_s,
                active_features: active,
                iters,
                gap,
                zeros,
                nonzeros: p - zeros,
                groups_rejected: stats.groups_rejected,
                features_rejected: stats.features_rejected_l2,
                layers,
                dynamic_evicted,
                kkt_readmitted,
                budget_exhausted,
                certified_suboptimality: certify(gap),
                ws_rounds,
                ws_final_size,
            },
            screen_s,
            solve_s,
        }
    }
}

/// Map a solver's final duality gap to the step's certified absolute
/// suboptimality bound: the gap itself when it is a number (clamped at 0 —
/// tiny negative values are f32 evaluation noise on a converged solve),
/// `+∞` when the gap evaluation went non-finite (poisoned input; the β the
/// solver returned then certifies nothing).
fn certify(gap: f64) -> f64 {
    if gap.is_finite() {
        gap.max(0.0)
    } else {
        f64::INFINITY
    }
}

/// The no-screening SGL baseline engine: identical grid and warm starts,
/// full matrix every step (the paper's "solver" row in Tables 1–2).
pub(crate) struct BaselineEngine<'a, M: DesignMatrix> {
    cfg: &'a PathConfig,
    prob: SglProblem<'a, M>,
    lambda_max: f64,
    // One set of spectral constants reused across the path — the full
    // matrix never changes. The recipes match the solvers' self-computing
    // fallbacks exactly.
    lip: Option<f64>,
    group_l: Option<Vec<f64>>,
    coloring: Option<GroupColoring>,
    beta: Vec<f32>,
    deadline: Option<std::time::Instant>,
}

impl<'a, M: DesignMatrix> BaselineEngine<'a, M> {
    pub(crate) fn new(
        x: &'a M,
        y: &'a [f32],
        groups: &'a GroupStructure,
        cfg: &'a PathConfig,
    ) -> BaselineEngine<'a, M> {
        cfg.validate();
        let prob = SglProblem::new(x, y, groups);
        let p = prob.n_features();
        let lambda_max = sgl_lambda_max(&prob, cfg.alpha).lambda_max;
        let lip = match cfg.solver {
            SolverKind::Fista => Some(lipschitz(&prob)),
            SolverKind::Bcd => None,
        };
        let group_l = match cfg.solver {
            SolverKind::Bcd => Some(bcd_group_lipschitz(x, &groups.ranges())),
            SolverKind::Fista => None,
        };
        let coloring = match cfg.solver {
            SolverKind::Bcd if cfg.parallel_bcd_groups => {
                Some(GroupColoring::compute(x, groups))
            }
            _ => None,
        };
        BaselineEngine {
            cfg,
            prob,
            lambda_max,
            lip,
            group_l,
            coloring,
            beta: vec![0.0; p],
            deadline: path_deadline(cfg.max_seconds),
        }
    }
}

impl<M: DesignMatrix> PathEngine for BaselineEngine<'_, M> {
    type Step = PathStep;

    fn lambda_max(&self) -> f64 {
        self.lambda_max
    }

    fn grid_shape(&self) -> (f64, usize) {
        (self.cfg.lambda_min_ratio, self.cfg.n_lambda)
    }

    fn preamble_s(&self) -> f64 {
        // The baseline reports no screening time at all (its spectral
        // setup is the solver's own cost, as in the paper's tables).
        0.0
    }

    fn zero_step(&self, lambda: f64) -> PathStep {
        let p = self.prob.n_features();
        PathStep {
            lambda,
            r1: 0.0,
            r2: 0.0,
            screen_s: 0.0,
            solve_s: 0.0,
            active_features: p,
            iters: 0,
            gap: 0.0,
            zeros: p,
            nonzeros: 0,
            groups_rejected: 0,
            features_rejected: 0,
            layers: Vec::new(),
            dynamic_evicted: 0,
            kkt_readmitted: 0,
            budget_exhausted: false,
            certified_suboptimality: 0.0,
            ws_rounds: 0,
            ws_final_size: 0,
        }
    }

    fn beta(&self) -> &[f32] {
        &self.beta
    }

    fn deadline(&self) -> Option<std::time::Instant> {
        self.deadline
    }

    fn step(&mut self, lambda: f64, _lambda_bar: f64) -> EngineStep<PathStep> {
        let p = self.prob.n_features();
        let params = SglParams::from_alpha_lambda(self.cfg.alpha, lambda);
        let ts = Timer::start();
        let res = solve(
            &self.prob,
            &params,
            Some(&self.beta),
            self.cfg,
            self.cfg.tol,
            self.lip,
            self.group_l.as_deref(),
            self.coloring.as_ref(),
            None,
            self.deadline,
        );
        let solve_s = ts.elapsed_s();
        self.beta = res.beta;
        let zeros = ops::count_zeros(&self.beta);
        EngineStep {
            step: PathStep {
                lambda,
                r1: 0.0,
                r2: 0.0,
                screen_s: 0.0,
                solve_s,
                active_features: p,
                iters: res.iters,
                gap: res.gap,
                zeros,
                nonzeros: p - zeros,
                groups_rejected: 0,
                features_rejected: 0,
                layers: Vec::new(),
                dynamic_evicted: 0,
                kkt_readmitted: 0,
                budget_exhausted: res.budget_exhausted,
                certified_suboptimality: certify(res.gap),
                ws_rounds: 0,
                ws_final_size: 0,
            },
            screen_s: 0.0,
            solve_s,
        }
    }
}

// ---------------------------------------------------------------------------
// Nonnegative-Lasso / DPC engines
// ---------------------------------------------------------------------------

/// The DPC-screened nonnegative-Lasso path engine (Section 6.2's protocol).
pub(crate) struct DpcEngine<'a, M: DesignMatrix> {
    x: &'a M,
    cfg: &'a DpcPathConfig,
    prob: NonnegProblem<'a, M>,
    col_norms: Vec<f64>,
    lmax: f64,
    argmax_col: usize,
    /// Path-level `‖X‖₂²` cache — valid step bound for every survivor view.
    path_lip: f64,
    refresher: Option<ScalarRefresher>,
    beta: Vec<f32>,
    resid: Vec<f32>,
    corr: Vec<f32>,
    preamble_s: f64,
    /// Path-level wall-clock deadline derived once from
    /// `cfg.max_seconds` — same budget contract as the TLFre engine.
    deadline: Option<std::time::Instant>,
}

impl<'a, M: DesignMatrix> DpcEngine<'a, M> {
    pub(crate) fn new(x: &'a M, y: &'a [f32], cfg: &'a DpcPathConfig) -> DpcEngine<'a, M> {
        cfg.validate();
        let prob = NonnegProblem::new(x, y);
        let p = x.cols();
        let n = x.rows();
        let t = Timer::start();
        let col_norms = x.col_norms();
        let (lmax, argmax_col) = nonneg_lambda_max(&prob);
        // Path-level Lipschitz cache (counted as screening time):
        // `nonneg_lipschitz` is the solver's own recipe — exact for the
        // full problem, a valid upper bound for every survivor view.
        let path_lip = nonneg_lipschitz(x);
        let preamble_s = t.elapsed_s();
        let refresher = cfg.lipschitz_refresh_every.map(|k| ScalarRefresher::new(k, p));
        DpcEngine {
            x,
            cfg,
            prob,
            col_norms,
            lmax,
            argmax_col,
            path_lip,
            refresher,
            beta: vec![0.0; p],
            resid: vec![0.0; n],
            corr: vec![0.0; p],
            preamble_s,
            deadline: path_deadline(cfg.max_seconds),
        }
    }
}

impl<M: DesignMatrix> PathEngine for DpcEngine<'_, M> {
    type Step = DpcStep;

    fn lambda_max(&self) -> f64 {
        self.lmax
    }

    fn grid_shape(&self) -> (f64, usize) {
        (self.cfg.lambda_min_ratio, self.cfg.n_lambda)
    }

    fn preamble_s(&self) -> f64 {
        self.preamble_s
    }

    fn zero_step(&self, lambda: f64) -> DpcStep {
        DpcStep {
            lambda,
            rejection: 1.0,
            screen_s: 0.0,
            solve_s: 0.0,
            active_features: 0,
            iters: 0,
            zeros: self.x.cols(),
            dynamic_evicted: 0,
            budget_exhausted: false,
        }
    }

    fn beta(&self) -> &[f32] {
        &self.beta
    }

    fn deadline(&self) -> Option<std::time::Instant> {
        self.deadline
    }

    fn step(&mut self, lambda: f64, lambda_bar: f64) -> EngineStep<DpcStep> {
        let cfg = self.cfg;
        let x = self.x;
        let p = x.cols();
        // Feasibility-scaled dual point + gap-based radius inflation (see
        // the TLFre engine for the rationale).
        let ts = Timer::start();
        x.residual(&self.beta, self.prob.y, &mut self.resid);
        x.matvec_t(&self.resid, &mut self.corr);
        let (gap_raw, s_feas) = crate::nonneg::duality_gap(
            &self.prob,
            lambda_bar,
            &self.beta,
            &self.resid,
            &self.corr,
        );
        let gap_bar = gap_raw * cfg.gap_inflation;
        let theta_bar: Vec<f32> =
            self.resid.iter().map(|&v| (v as f64 * s_feas / lambda_bar) as f32).collect();
        let out = crate::screening::dpc::dpc_screen_inexact(
            &self.prob,
            lambda,
            lambda_bar,
            &theta_bar,
            gap_bar,
            self.lmax,
            self.argmax_col,
            &self.col_norms,
        );
        let active: Vec<usize> = out.active_features();
        // Refresh inside the screening timer: the amortized power
        // iteration is spectral preamble work, attributed to screen_s so
        // solve-time comparisons against the cached mode stay fair.
        let step_lip = match (&mut self.refresher, active.is_empty()) {
            (Some(rf), false) => rf.step(&active, self.path_lip, || {
                nonneg_lipschitz(&ScreenedView::new(x, active.clone()))
            }),
            _ => self.path_lip,
        };
        let screen_s = ts.elapsed_s();

        let ts = Timer::start();
        let mut dyn_evicted_full: Vec<usize> = Vec::new();
        let (iters, active_n, dynamic_evicted, budget_exhausted) = if active.is_empty() {
            self.beta.fill(0.0);
            (0usize, 0usize, 0usize, false)
        } else {
            // Zero-copy survivor view — no per-λ column gather.
            let xr = ScreenedView::new(x, active.clone());
            let rp = NonnegProblem::new(&xr, self.prob.y);
            let warm: Vec<f32> = active.iter().map(|&j| self.beta[j]).collect();
            // In-solver dynamic GAP screening (Theorem 22 sphere on the
            // shrinking duality gap), projected onto the survivor view.
            let dyn_state = if cfg.dynamic_screening {
                let cn: Vec<f64> = active.iter().map(|&j| self.col_norms[j]).collect();
                Some(RefCell::new(GapSafeDynamicNonneg::new(cn)))
            } else {
                None
            };
            let res = solve_nonneg(
                &rp,
                lambda,
                Some(&warm),
                &NonnegOptions {
                    tol: cfg.tol,
                    max_iter: cfg.max_iter,
                    lipschitz: Some(step_lip),
                    dynamic_screen: dyn_state.as_ref(),
                    deadline: self.deadline,
                    ..Default::default()
                },
            );
            self.beta.fill(0.0);
            for (k, &j) in active.iter().enumerate() {
                self.beta[j] = res.beta[k];
            }
            let evicted = match dyn_state {
                Some(st) => {
                    let st = st.into_inner();
                    if cfg.verify_safety {
                        dyn_evicted_full
                            .extend(st.evicted_ids().iter().map(|&k| active[k]));
                    }
                    st.evicted()
                }
                None => 0,
            };
            (res.iters, active.len(), evicted, res.budget_exhausted)
        };
        let solve_s = ts.elapsed_s();

        if cfg.verify_safety {
            // Exact cached constant for the full problem.
            let full = solve_nonneg(
                &self.prob,
                lambda,
                None,
                &NonnegOptions {
                    tol: cfg.tol,
                    max_iter: cfg.max_iter,
                    lipschitz: Some(self.path_lip),
                    ..Default::default()
                },
            );
            for j in 0..p {
                if !out.feature_kept[j] {
                    assert!(
                        full.beta[j].abs() < 1e-4,
                        "DPC SAFETY VIOLATION at λ={lambda}: feature {j} β={}",
                        full.beta[j]
                    );
                }
            }
            // Dynamic evictions verified against the same reference solve.
            for &j in &dyn_evicted_full {
                assert!(
                    full.beta[j].abs() < 1e-4,
                    "DPC DYNAMIC SAFETY VIOLATION at λ={lambda}: feature {j} evicted \
                     in-solver but β={}",
                    full.beta[j]
                );
            }
        }

        let zeros = ops::count_zeros(&self.beta);
        EngineStep {
            step: DpcStep {
                lambda,
                rejection: out.rejected as f64 / zeros.max(1) as f64,
                screen_s,
                solve_s,
                active_features: active_n,
                iters,
                zeros,
                dynamic_evicted,
                budget_exhausted,
            },
            screen_s,
            solve_s,
        }
    }
}

/// The no-screening nonnegative-Lasso baseline engine (Table 3's "solver").
pub(crate) struct DpcBaselineEngine<'a, M: DesignMatrix> {
    cfg: &'a DpcPathConfig,
    prob: NonnegProblem<'a, M>,
    lmax: f64,
    /// The solver's canonical step-bound recipe (2% from-below inflation).
    lip: f64,
    beta: Vec<f32>,
    deadline: Option<std::time::Instant>,
}

impl<'a, M: DesignMatrix> DpcBaselineEngine<'a, M> {
    pub(crate) fn new(x: &'a M, y: &'a [f32], cfg: &'a DpcPathConfig) -> DpcBaselineEngine<'a, M> {
        cfg.validate();
        let prob = NonnegProblem::new(x, y);
        let (lmax, _) = nonneg_lambda_max(&prob);
        let lip = nonneg_lipschitz(x);
        DpcBaselineEngine {
            cfg,
            prob,
            lmax,
            lip,
            beta: vec![0.0; x.cols()],
            deadline: path_deadline(cfg.max_seconds),
        }
    }
}

impl<M: DesignMatrix> PathEngine for DpcBaselineEngine<'_, M> {
    type Step = DpcStep;

    fn lambda_max(&self) -> f64 {
        self.lmax
    }

    fn grid_shape(&self) -> (f64, usize) {
        (self.cfg.lambda_min_ratio, self.cfg.n_lambda)
    }

    fn preamble_s(&self) -> f64 {
        0.0
    }

    fn zero_step(&self, lambda: f64) -> DpcStep {
        let p = self.beta.len();
        DpcStep {
            lambda,
            rejection: 0.0,
            screen_s: 0.0,
            solve_s: 0.0,
            active_features: p,
            iters: 0,
            zeros: p,
            dynamic_evicted: 0,
            budget_exhausted: false,
        }
    }

    fn beta(&self) -> &[f32] {
        &self.beta
    }

    fn deadline(&self) -> Option<std::time::Instant> {
        self.deadline
    }

    fn step(&mut self, lambda: f64, _lambda_bar: f64) -> EngineStep<DpcStep> {
        let p = self.beta.len();
        let ts = Timer::start();
        let res = solve_nonneg(
            &self.prob,
            lambda,
            Some(&self.beta),
            &NonnegOptions {
                tol: self.cfg.tol,
                max_iter: self.cfg.max_iter,
                lipschitz: Some(self.lip),
                deadline: self.deadline,
                ..Default::default()
            },
        );
        let solve_s = ts.elapsed_s();
        self.beta = res.beta;
        EngineStep {
            step: DpcStep {
                lambda,
                rejection: 0.0,
                screen_s: 0.0,
                solve_s,
                active_features: p,
                iters: res.iters,
                zeros: ops::count_zeros(&self.beta),
                dynamic_evicted: 0,
                budget_exhausted: res.budget_exhausted,
            },
            screen_s: 0.0,
            solve_s,
        }
    }
}

// ---------------------------------------------------------------------------
// Checkpointing seam
// ---------------------------------------------------------------------------

/// The mutable engine state a checkpoint must capture for bitwise resume
/// parity: the warm-started β plus the Lipschitz refreshers' cadence
/// counters, masks and cached values. Everything else an engine holds is
/// either borrowed input (X, y, groups, config), a pure function of that
/// input recomputed identically at reconstruction (λmax, screening
/// context, spectral cache, coloring), or per-step scratch rebuilt from β
/// at the top of every step (residual, correlation sweep). Dynamic GAP
/// state is created fresh per reduced solve and never crosses steps.
pub(crate) struct EngineSnapshot {
    pub beta: Vec<f32>,
    /// [`ScalarRefresher::snapshot`] when the engine runs one (FISTA +
    /// `lipschitz_refresh_every`).
    pub scalar: Option<(usize, Vec<bool>, Option<f64>)>,
    /// [`GroupRefresher::snapshot`] when the engine runs one (BCD +
    /// `lipschitz_refresh_every`).
    pub group: Option<(usize, Vec<bool>, Vec<f64>)>,
}

/// Engines that can round-trip their mutable state through an
/// [`EngineSnapshot`] — the seam `coordinator::checkpoint` builds
/// kill-and-resume on. Restoring a snapshot taken after grid step *i* and
/// continuing from step *i + 1* must be bitwise identical to never having
/// stopped; the snapshot/restore pair here and the refresher contract in
/// [`super::refresh`] carry that guarantee.
pub(crate) trait Checkpointable {
    fn snapshot(&self) -> EngineSnapshot;
    fn restore(&mut self, snap: EngineSnapshot);
}

impl<M: DesignMatrix> Checkpointable for TlfreEngine<'_, M> {
    fn snapshot(&self) -> EngineSnapshot {
        EngineSnapshot {
            beta: self.beta.clone(),
            scalar: self.scalar_refresh.as_ref().map(|r| r.snapshot()),
            group: self.group_refresh.as_ref().map(|r| r.snapshot()),
        }
    }

    fn restore(&mut self, snap: EngineSnapshot) {
        assert_eq!(snap.beta.len(), self.beta.len(), "checkpoint β dimension mismatch");
        self.beta = snap.beta;
        if let (Some(rf), Some((since, mask, value))) = (&mut self.scalar_refresh, snap.scalar) {
            rf.restore(since, mask, value);
        }
        if let (Some(rf), Some((since, mask, values))) = (&mut self.group_refresh, snap.group) {
            rf.restore(since, mask, values);
        }
    }
}

// ---------------------------------------------------------------------------
// Public entry points
// ---------------------------------------------------------------------------

/// Stream a TLFre-screened SGL path into `sink`. `run_tlfre_path` is this
/// with a [`StepSink`]; cross-validation is this with a [`HoldoutSink`]
/// per fold×α.
pub fn drive_tlfre_path<M: DesignMatrix, K: PathSink<PathStep>>(
    x: &M,
    y: &[f32],
    groups: &GroupStructure,
    cfg: &PathConfig,
    sink: &mut K,
) -> PathTotals {
    drive(TlfreEngine::new(x, y, groups, cfg), sink)
}

/// [`drive_tlfre_path`] with an explicit, possibly custom,
/// [`ScreenPipeline`] instead of the one named by `cfg.screen`. This is
/// the extension seam for user-defined
/// [`crate::screening::rule::ScreeningRule`]s: heuristic rules compose
/// automatically with the driver's KKT recovery loop (violators among the
/// discarded coordinates are re-admitted and the reduced problem
/// re-solved), so a wrong rejection costs a re-solve, never correctness —
/// the regression test in `tests/dynamic_screening.rs` drives a
/// deliberately-wrong rule through this entry point.
pub fn drive_tlfre_path_with_pipeline<M: DesignMatrix, K: PathSink<PathStep>>(
    x: &M,
    y: &[f32],
    groups: &GroupStructure,
    cfg: &PathConfig,
    pipeline: ScreenPipeline<M>,
    sink: &mut K,
) -> PathTotals {
    drive(TlfreEngine::with_pipeline(x, y, groups, cfg, pipeline), sink)
}

/// Stream the no-screening SGL baseline path into `sink`.
pub fn drive_baseline_path<M: DesignMatrix, K: PathSink<PathStep>>(
    x: &M,
    y: &[f32],
    groups: &GroupStructure,
    cfg: &PathConfig,
    sink: &mut K,
) -> PathTotals {
    drive(BaselineEngine::new(x, y, groups, cfg), sink)
}

/// Stream a DPC-screened nonnegative-Lasso path into `sink`.
pub fn drive_dpc_path<M: DesignMatrix, K: PathSink<DpcStep>>(
    x: &M,
    y: &[f32],
    cfg: &DpcPathConfig,
    sink: &mut K,
) -> PathTotals {
    drive(DpcEngine::new(x, y, cfg), sink)
}

/// Stream the no-screening nonnegative-Lasso baseline path into `sink`.
pub fn drive_nonneg_baseline<M: DesignMatrix, K: PathSink<DpcStep>>(
    x: &M,
    y: &[f32],
    cfg: &DpcPathConfig,
    sink: &mut K,
) -> PathTotals {
    drive(DpcBaselineEngine::new(x, y, cfg), sink)
}

#[cfg(test)]
mod tests {
    use super::super::runner::SolveControls;
    use super::*;
    use crate::data::synthetic::{generate_synthetic, SyntheticSpec};

    #[test]
    fn sinks_see_every_grid_point_with_matching_beta() {
        // Two sinks driven over the same engine config must agree with the
        // runner facade: one β per λ, λmax first, β₀ ≡ 0.
        let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(25, 100, 10), 611);
        let cfg = PathConfig {
            alpha: 1.0,
            controls: SolveControls {
                n_lambda: 7,
                lambda_min_ratio: 0.1,
                tol: 1e-6,
                ..Default::default()
            },
            ..Default::default()
        };
        let mut steps = StepSink::new();
        let mut betas = CoefficientSink::new();
        let a = drive_tlfre_path(&ds.x, &ds.y, &ds.groups, &cfg, &mut steps);
        let b = drive_tlfre_path(&ds.x, &ds.y, &ds.groups, &cfg, &mut betas);
        assert_eq!(steps.steps.len(), 7);
        assert_eq!(betas.betas.len(), 7);
        assert!((a.lambda_max - b.lambda_max).abs() < 1e-15);
        assert!(betas.betas[0].iter().all(|&v| v == 0.0), "λmax step must be all-zero");
        for (s, bv) in steps.steps.iter().zip(&betas.betas) {
            let nnz = bv.iter().filter(|&&v| v != 0.0).count();
            assert_eq!(nnz, s.nonzeros, "sink β disagrees with step stats at λ={}", s.lambda);
        }
    }

    #[test]
    fn holdout_sink_matches_manual_prediction() {
        let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(30, 100, 10), 612);
        let cfg = PathConfig {
            alpha: 1.0,
            controls: SolveControls {
                n_lambda: 6,
                lambda_min_ratio: 0.1,
                tol: 1e-6,
                ..Default::default()
            },
            ..Default::default()
        };
        // Hold out the same matrix it was trained on (a pure plumbing
        // check — the numbers must equal a manual β-walk evaluation).
        let mut holdout = HoldoutSink::new(&ds.x, &ds.y[..]);
        let mut betas = CoefficientSink::new();
        drive_tlfre_path(&ds.x, &ds.y, &ds.groups, &cfg, &mut holdout);
        drive_tlfre_path(&ds.x, &ds.y, &ds.groups, &cfg, &mut betas);
        assert_eq!(holdout.mse.len(), 6);
        let n = ds.x.rows();
        for (li, bv) in betas.betas.iter().enumerate() {
            let mut pred = vec![0.0f32; n];
            ds.x.matvec(bv, &mut pred);
            let mut e = 0.0f64;
            for (p, t) in pred.iter().zip(&ds.y) {
                let d = (p - t) as f64;
                e += d * d;
            }
            let want = e / n as f64;
            assert_eq!(want.to_bits(), holdout.mse[li].to_bits(), "λ index {li}");
            let nnz = bv.iter().filter(|&&v| v != 0.0).count() as f64;
            assert_eq!(nnz, holdout.nnz[li], "λ index {li}");
        }
    }

    #[test]
    fn single_point_grid_is_the_lambda_max_step() {
        let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(20, 60, 6), 613);
        let cfg = PathConfig {
            controls: SolveControls { n_lambda: 1, ..Default::default() },
            ..Default::default()
        };
        let mut sink = StepSink::new();
        let totals = drive_tlfre_path(&ds.x, &ds.y, &ds.groups, &cfg, &mut sink);
        assert_eq!(sink.steps.len(), 1);
        let s = &sink.steps[0];
        assert!((s.lambda - totals.lambda_max).abs() < 1e-12);
        assert_eq!(s.nonzeros, 0, "β must be exactly zero at λmax");
        assert_eq!(totals.solve_total_s, 0.0);
    }
}
