//! Amortized per-view Lipschitz refresh for the path runners.
//!
//! The path-level spectral cache (PR 2) reuses full-matrix constants for
//! every reduced solve — always valid (`σmax(X[:,S]) ≤ σmax(X)`), never
//! tight. The exact mode (`PathConfig::exact_view_lipschitz`) recomputes on
//! every survivor view — tight, but pays power iteration at every λ.
//! `PathConfig::lipschitz_refresh_every = Some(K)` is the amortized middle:
//! recompute on the **current survivor view** every K path steps (cost
//! counted as screening time, like the rest of the spectral preamble), and
//! between refreshes reuse the refreshed value *only while it is provably
//! an upper bound*.
//!
//! ## The subset-validity rule
//!
//! A value measured on survivor set `S_r` bounds the current step's
//! operator norm iff the current survivors are a **subset** of `S_r`
//! (column-subset operator norms only shrink). TLFre survivor sets usually
//! *grow* as λ decreases, so the refreshers track the feature mask at the
//! last refresh and, whenever new survivors appear before the next refresh
//! is due, fall back to the full-matrix cached constant — conservative but
//! always safe. An underestimated step bound could destabilize FISTA; this
//! rule makes that impossible by construction (unit-tested below).
//!
//! Two trackers cover the three consumers: [`ScalarRefresher`] for the
//! single `‖X[:,S]‖₂²` bound (SGL-FISTA, nonneg/DPC) and
//! [`GroupRefresher`] for BCD's per-group `‖X_g[:,S]‖₂²` bounds (validity
//! is then per *group*: a group whose surviving columns stayed inside the
//! refresh-time mask keeps its tight value even if other groups grew).
//!
//! Interplay with **dynamic** screening (`PathConfig::screen` GAP modes):
//! in-solver evictions only *shrink* the survivor set mid-solve, and a
//! column-subset operator norm never grows, so a bound that was valid for
//! the reduced problem at solve start stays valid for every dynamically
//! shrunken view — no feedback from the solver into the refreshers is
//! needed. KKT re-admission rounds (heuristic pipelines) can *grow* the
//! set, so the driver's re-solve rounds fall back to the always-valid
//! full-matrix constants instead of the refreshed ones.

/// Amortized refresher for a single spectral bound.
pub(crate) struct ScalarRefresher {
    every: usize,
    /// Steps since the last refresh; starts ≥ `every` so the first reduced
    /// solve always refreshes (survivor sets are smallest — and refreshes
    /// cheapest — at the top of the path).
    since: usize,
    /// Survivor-feature mask (full feature space) at the last refresh.
    mask: Vec<bool>,
    value: Option<f64>,
}

impl ScalarRefresher {
    pub fn new(every: usize, p: usize) -> ScalarRefresher {
        ScalarRefresher {
            every: every.max(1),
            since: usize::MAX,
            mask: vec![false; p],
            value: None,
        }
    }

    /// The step bound for a solve over `survivors` (full-space column ids).
    /// Calls `recompute` — the solver's own recipe on the current view —
    /// when the refresh is due; the caller times it as screening work.
    pub fn step(
        &mut self,
        survivors: &[usize],
        fallback: f64,
        recompute: impl FnOnce() -> f64,
    ) -> f64 {
        if self.since >= self.every {
            let v = recompute();
            self.value = Some(v);
            self.mask.fill(false);
            for &j in survivors {
                self.mask[j] = true;
            }
            self.since = 1;
            return v;
        }
        self.since += 1;
        match self.value {
            Some(v) if survivors.iter().all(|&j| self.mask[j]) => v,
            _ => fallback,
        }
    }

    /// Snapshot for checkpointing: `(since, mask, value)`. Restoring this
    /// exact tuple makes every subsequent [`Self::step`] decision —
    /// refresh-due cadence and subset-validity — identical to the
    /// uninterrupted run, which the bitwise resume-parity guarantee
    /// depends on.
    pub fn snapshot(&self) -> (usize, Vec<bool>, Option<f64>) {
        (self.since, self.mask.clone(), self.value)
    }

    /// Restore a [`Self::snapshot`] (see there). `every` is not part of
    /// the snapshot: it is re-derived from `PathConfig`, and a config
    /// mismatch is rejected before restore by the checkpoint fingerprint.
    pub fn restore(&mut self, since: usize, mask: Vec<bool>, value: Option<f64>) {
        assert_eq!(mask.len(), self.mask.len(), "refresher mask dimension mismatch");
        self.since = since;
        self.mask = mask;
        self.value = value;
    }
}

/// Amortized refresher for per-group spectral bounds (BCD paths).
pub(crate) struct GroupRefresher {
    every: usize,
    since: usize,
    mask: Vec<bool>,
    /// Refreshed `‖X_g[:,S_r]‖₂²` per **full** group id; NaN = never
    /// computed. Staleness is impossible: a value is only consulted when
    /// the group's current columns sit inside the *latest* mask, and any
    /// group with a masked column was recomputed at that same refresh.
    values: Vec<f64>,
}

impl GroupRefresher {
    pub fn new(every: usize, p: usize, n_groups: usize) -> GroupRefresher {
        GroupRefresher {
            every: every.max(1),
            since: usize::MAX,
            mask: vec![false; p],
            values: vec![f64::NAN; n_groups],
        }
    }

    /// Per-reduced-group step bounds for this solve.
    ///
    /// * `feature_map` — reduced column → full column (ascending per group);
    /// * `red_ranges` — reduced groups as `[start, end)` over `feature_map`;
    /// * `group_map` — reduced group → full group id;
    /// * `fallback` — the full-matrix per-group cache (indexed by full id);
    /// * `recompute` — the solver's recipe on the current view, returning
    ///   one value per reduced group (in reduced order).
    pub fn step(
        &mut self,
        feature_map: &[usize],
        red_ranges: &[(usize, usize)],
        group_map: &[usize],
        fallback: &[f64],
        recompute: impl FnOnce() -> Vec<f64>,
    ) -> Vec<f64> {
        debug_assert_eq!(red_ranges.len(), group_map.len());
        if self.since >= self.every {
            let vals = recompute();
            debug_assert_eq!(vals.len(), group_map.len());
            self.mask.fill(false);
            for &j in feature_map {
                self.mask[j] = true;
            }
            for (i, &g) in group_map.iter().enumerate() {
                self.values[g] = vals[i];
            }
            self.since = 1;
            return vals;
        }
        self.since += 1;
        red_ranges
            .iter()
            .zip(group_map)
            .map(|(&(s, e), &g)| {
                let inside = feature_map[s..e].iter().all(|&j| self.mask[j]);
                if inside && self.values[g].is_finite() {
                    self.values[g]
                } else {
                    fallback[g]
                }
            })
            .collect()
    }

    /// Snapshot for checkpointing: `(since, mask, values)` — same
    /// resume-parity contract as [`ScalarRefresher::snapshot`]. NaN
    /// entries in `values` mean "never computed" and round-trip as NaN.
    pub fn snapshot(&self) -> (usize, Vec<bool>, Vec<f64>) {
        (self.since, self.mask.clone(), self.values.clone())
    }

    /// Restore a [`Self::snapshot`].
    pub fn restore(&mut self, since: usize, mask: Vec<bool>, values: Vec<f64>) {
        assert_eq!(mask.len(), self.mask.len(), "refresher mask dimension mismatch");
        assert_eq!(values.len(), self.values.len(), "refresher group dimension mismatch");
        self.since = since;
        self.mask = mask;
        self.values = values;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_first_step_always_refreshes() {
        let mut rf = ScalarRefresher::new(5, 8);
        let v = rf.step(&[0, 3], 100.0, || 7.0);
        assert_eq!(v, 7.0);
    }

    #[test]
    fn scalar_subset_reuses_superset_falls_back() {
        let mut rf = ScalarRefresher::new(10, 8);
        assert_eq!(rf.step(&[1, 2, 5], 100.0, || 7.0), 7.0);
        // Subset of the refresh-time survivors → refreshed value, and
        // recompute must NOT run.
        assert_eq!(rf.step(&[2, 5], 100.0, || panic!("off-cadence recompute")), 7.0);
        // A new survivor appeared → conservative full-matrix fallback.
        assert_eq!(rf.step(&[2, 6], 100.0, || panic!("off-cadence recompute")), 100.0);
        // Back inside the mask → the refreshed value is valid again.
        assert_eq!(rf.step(&[1], 100.0, || panic!("off-cadence recompute")), 7.0);
    }

    #[test]
    fn scalar_cadence_recomputes_every_k() {
        let mut rf = ScalarRefresher::new(3, 4);
        let mut recomputes = 0;
        for step in 0..9 {
            let fresh = step % 3 == 0;
            let v = rf.step(&[0], 100.0, || {
                recomputes += 1;
                recomputes as f64
            });
            if fresh {
                assert_eq!(v, recomputes as f64, "step {step} must refresh");
            }
        }
        assert_eq!(recomputes, 3, "9 steps at K=3 → 3 refreshes");
    }

    #[test]
    fn scalar_every_one_recomputes_each_step() {
        let mut rf = ScalarRefresher::new(1, 2);
        let mut n = 0;
        for _ in 0..4 {
            rf.step(&[0], 100.0, || {
                n += 1;
                n as f64
            });
        }
        assert_eq!(n, 4);
    }

    #[test]
    fn group_per_group_validity_is_independent() {
        let mut rf = GroupRefresher::new(10, 6, 3);
        // Refresh over reduced problem: groups 0 and 2 survive with
        // features {0,1} and {4}.
        let vals = rf.step(&[0, 1, 4], &[(0, 2), (2, 3)], &[0, 2], &[9.0, 9.0, 9.0], || {
            vec![1.0, 3.0]
        });
        assert_eq!(vals, vec![1.0, 3.0]);
        // Next step: group 0 shrank to {1} (valid → tight value), group 1
        // reappeared with {2} (not in mask → fallback), group 2 grew to
        // {4, 5} (5 not in mask → fallback).
        let vals = rf.step(
            &[1, 2, 4, 5],
            &[(0, 1), (1, 2), (2, 4)],
            &[0, 1, 2],
            &[9.0, 8.0, 7.0],
            || panic!("off-cadence recompute"),
        );
        assert_eq!(vals, vec![1.0, 8.0, 7.0]);
    }

    #[test]
    fn group_cadence_refresh_overwrites_mask_and_values() {
        let mut rf = GroupRefresher::new(2, 4, 2);
        assert_eq!(rf.step(&[0], &[(0, 1)], &[0], &[9.0, 9.0], || vec![1.0]), vec![1.0]);
        // Off-cadence: group 1 unknown → fallback.
        assert_eq!(
            rf.step(&[2], &[(0, 1)], &[1], &[9.0, 8.0], || panic!("off-cadence")),
            vec![8.0]
        );
        // Due again: refresh over group 1 only.
        assert_eq!(rf.step(&[2, 3], &[(0, 2)], &[1], &[9.0, 8.0], || vec![2.0]), vec![2.0]);
        // Group 0's old value is now invalid (feature 0 not in the latest
        // mask) → fallback, even though a stale value exists.
        assert_eq!(
            rf.step(&[0, 2], &[(0, 1), (1, 2)], &[0, 1], &[9.0, 8.0], || panic!("off-cadence")),
            vec![9.0, 2.0]
        );
    }
}
