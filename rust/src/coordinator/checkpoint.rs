//! Kill-safe checkpoint/resume for TLFre path runs (`TLFRECK1` sidecar).
//!
//! [`run_tlfre_path_checkpointed`] walks the same grid as
//! `run_tlfre_path`, but every K completed grid points it atomically
//! writes a sidecar file capturing everything a fresh process needs to
//! continue the walk **bitwise identically**: the completed per-λ step
//! records, one full-space β per completed λ, and the engine's mutable
//! state (the warm-started β is the last per-λ β; the Lipschitz
//! refreshers' cadence counters, masks and cached values ride along — see
//! `coordinator::driver::EngineSnapshot` for why that is the complete
//! list). A run relaunched with [`CheckpointOptions::resume`] replays the
//! recorded prefix, restores the engine, and continues from the next grid
//! point; `tests/checkpoint_resume.rs` asserts the continuation equals an
//! uninterrupted run coefficient-for-coefficient at every worker count.
//!
//! ## Format
//!
//! Little-endian, same header-validation discipline as the `TLFREDS1`
//! dataset container (`data::io`): magic and version first, then a
//! fixed-size header whose every field is range-checked — and checked
//! against the resuming run's problem/config fingerprint — before any
//! payload allocation, then a length-validated payload parsed by a
//! bounds-checked cursor. A truncated, corrupt, or wrong-config file
//! yields a typed error, never garbage state.
//!
//! ```text
//! magic[8]=TLFRECK1 | version u32
//! | n u64 | p u64 | g u64 | n_lambda u64 | completed u64
//! | alpha f64 | lambda_min_ratio f64 | tol f64 | gap_inflation f64
//! | lambda_max f64 | solver u8 | screen u8 | flags u8 | has_scalar u8
//! | has_group u8 | pad[3] | refresh u64 | max_iter u64
//! | ws_max_rounds u64 | ws_growth f64
//! | screen_total_s f64 | solve_total_s f64 | payload_len u64
//! ```
//!
//! The working-set knobs are fingerprint fields (version 2): under a
//! `ws` pipeline they change the loose-round iterate trajectory and hence
//! the warm starts every later step inherits, so resuming under different
//! `ws_growth`/`ws_max_rounds` is a config mismatch, not a continuation.
//!
//! The payload holds the optional refresher snapshots followed by
//! `completed` step records, each a fixed-field `PathStep` plus its
//! per-rule layer counts and that step's full-space β (`p × f32`).
//! Floats round-trip by bit pattern (NaN refresher slots mean "never
//! computed" and are preserved exactly).
//!
//! ## Atomicity and crash windows
//!
//! Checkpoints are written to a `.tmp` sibling and renamed into place, so
//! a kill mid-write leaves either the previous complete checkpoint or
//! none — never a partial file at the target path. A kill *between*
//! checkpoints loses at most `every − 1` completed grid points; resume
//! recomputes them from the restored state, and because every kernel in
//! the path is deterministic the recomputed steps are bitwise identical
//! to the lost ones. See the "Failure modes & recovery" notes in
//! [`super`] (the coordinator module docs).

use super::driver::{Checkpointable, EngineSnapshot, PathEngine, TlfreEngine};
use super::path::log_lambda_grid;
use super::runner::{PathConfig, PathOutput, PathStep, SolverKind};
use crate::bail;
use crate::error::{Context, Result};
use crate::groups::GroupStructure;
use crate::linalg::DesignMatrix;
use crate::screening::rule::{LayerCount, Safety, ScreenKind};
use crate::sgl::fista::deadline_passed;
use std::path::{Path, PathBuf};

const MAGIC: &[u8; 8] = b"TLFRECK1";
const VERSION: u32 = 2;
/// Upper bound on per-step layer records — the built-in pipelines hold at
/// most two rules; anything larger in a file is corruption.
const MAX_LAYERS: usize = 64;

/// How a checkpointed path run writes and (optionally) resumes its sidecar.
#[derive(Debug, Clone)]
pub struct CheckpointOptions {
    /// Sidecar file path. Written atomically (temp sibling + rename); the
    /// temp sibling is `<file_name>.tmp` next to it.
    pub path: PathBuf,
    /// Save cadence in completed grid points (clamped to ≥ 1). A final
    /// checkpoint is always written when the grid completes.
    pub every: usize,
    /// Load `path` and continue the recorded run instead of starting over.
    /// The file's problem/config fingerprint must match this run exactly;
    /// a mismatch is a typed error, not a silent restart.
    pub resume: bool,
    /// Stop cleanly once this many total grid points are completed — the
    /// fault-injection hook behind the kill-and-resume tests and the
    /// checkpoint-overhead bench (a deterministic stand-in for `kill -9`
    /// that still exercises the exact save/restore path). `None` runs the
    /// whole grid.
    pub stop_after: Option<usize>,
}

impl CheckpointOptions {
    /// Options for a fresh checkpointed run with the default cadence
    /// (every 5 grid points).
    pub fn new(path: impl Into<PathBuf>) -> CheckpointOptions {
        CheckpointOptions { path: path.into(), every: 5, resume: false, stop_after: None }
    }
}

/// The problem/config fingerprint stored in every checkpoint and required
/// to match bit-for-bit on resume. λmax is part of it: it is a
/// deterministic function of (X, y, α), so it doubles as a cheap content
/// check on the dataset itself.
#[derive(Debug)]
struct CheckpointKey {
    n: u64,
    p: u64,
    n_groups: u64,
    n_lambda: u64,
    alpha: f64,
    lambda_min_ratio: f64,
    tol: f64,
    gap_inflation: f64,
    lambda_max: f64,
    solver: u8,
    screen: u8,
    /// Bit 0 `verify_safety`, 1 `materialize_reduced`, 2
    /// `exact_view_lipschitz`, 3 `parallel_bcd_groups`.
    flags: u8,
    /// `lipschitz_refresh_every` (0 = disabled).
    refresh: u64,
    max_iter: u64,
    /// Working-set outer-round cap (fingerprint even for non-ws pipelines;
    /// the stored bytes must round-trip exactly).
    ws_max_rounds: u64,
    /// Working-set geometric growth factor.
    ws_growth: f64,
}

fn solver_id(s: SolverKind) -> u8 {
    match s {
        SolverKind::Fista => 0,
        SolverKind::Bcd => 1,
    }
}

fn screen_id(s: ScreenKind) -> u8 {
    match s {
        ScreenKind::Tlfre => 0,
        ScreenKind::TlfreGap => 1,
        ScreenKind::Gap => 2,
        ScreenKind::StrongKkt => 3,
        ScreenKind::None => 4,
        ScreenKind::Ws => 5,
        ScreenKind::TlfreWs => 6,
        ScreenKind::WsGap => 7,
    }
}

fn rule_id(name: &str) -> Result<u8> {
    match name {
        "tlfre" => Ok(0),
        "gap" => Ok(1),
        "strong" => Ok(2),
        "ws" => Ok(3),
        other => Err(crate::anyhow!(
            "checkpointing supports the built-in screening rules only (got rule {other:?})"
        )),
    }
}

fn rule_name(id: u8) -> Result<&'static str> {
    match id {
        0 => Ok("tlfre"),
        1 => Ok("gap"),
        2 => Ok("strong"),
        3 => Ok("ws"),
        other => Err(crate::anyhow!("corrupt checkpoint: unknown rule id {other}")),
    }
}

impl CheckpointKey {
    fn new(
        n: usize,
        p: usize,
        n_groups: usize,
        cfg: &PathConfig,
        lambda_max: f64,
    ) -> CheckpointKey {
        CheckpointKey {
            n: n as u64,
            p: p as u64,
            n_groups: n_groups as u64,
            n_lambda: cfg.n_lambda as u64,
            alpha: cfg.alpha,
            lambda_min_ratio: cfg.lambda_min_ratio,
            tol: cfg.tol,
            gap_inflation: cfg.gap_inflation,
            lambda_max,
            solver: solver_id(cfg.solver),
            screen: screen_id(cfg.screen),
            flags: (cfg.verify_safety as u8)
                | (cfg.materialize_reduced as u8) << 1
                | (cfg.exact_view_lipschitz as u8) << 2
                | (cfg.parallel_bcd_groups as u8) << 3,
            refresh: cfg.lipschitz_refresh_every.map_or(0, |k| k as u64),
            max_iter: cfg.max_iter as u64,
            ws_max_rounds: cfg.ws_max_rounds as u64,
            ws_growth: cfg.ws_growth,
        }
    }

    /// Compare against a loaded key; f64 fields compare by bit pattern
    /// (resume parity needs the exact same grid, not an approximately
    /// equal one).
    fn matches(&self, other: &CheckpointKey) -> bool {
        self.n == other.n
            && self.p == other.p
            && self.n_groups == other.n_groups
            && self.n_lambda == other.n_lambda
            && self.alpha.to_bits() == other.alpha.to_bits()
            && self.lambda_min_ratio.to_bits() == other.lambda_min_ratio.to_bits()
            && self.tol.to_bits() == other.tol.to_bits()
            && self.gap_inflation.to_bits() == other.gap_inflation.to_bits()
            && self.lambda_max.to_bits() == other.lambda_max.to_bits()
            && self.solver == other.solver
            && self.screen == other.screen
            && self.flags == other.flags
            && self.refresh == other.refresh
            && self.max_iter == other.max_iter
            && self.ws_max_rounds == other.ws_max_rounds
            && self.ws_growth.to_bits() == other.ws_growth.to_bits()
    }
}

// ---------------------------------------------------------------------------
// Binary encode/decode
// ---------------------------------------------------------------------------

/// Little-endian append-only encoder (checkpoints are built in RAM and
/// written in one atomic pass).
struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn f32s(&mut self, xs: &[f32]) {
        for &x in xs {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    fn bools(&mut self, bs: &[bool]) {
        self.buf.extend(bs.iter().map(|&b| b as u8));
    }
}

/// Bounds-checked little-endian cursor: every read is validated against
/// the remaining buffer, so a truncated file fails with a typed error at
/// the exact field — and nothing is allocated past what the buffer can
/// actually back.
struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        if self.buf.len() - self.pos < n {
            bail!(
                "corrupt checkpoint: truncated while reading {what} \
                 (need {n} bytes at offset {}, have {})",
                self.pos,
                self.buf.len() - self.pos
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self, what: &str) -> Result<u8> {
        Ok(self.take(1, what)?[0])
    }

    fn u32(&mut self, what: &str) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4, what)?.try_into().unwrap()))
    }

    fn u64(&mut self, what: &str) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8, what)?.try_into().unwrap()))
    }

    fn f64(&mut self, what: &str) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8, what)?.try_into().unwrap()))
    }

    fn f32s(&mut self, n: usize, what: &str) -> Result<Vec<f32>> {
        let bytes = self.take(n * 4, what)?;
        Ok(bytes.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn f64s(&mut self, n: usize, what: &str) -> Result<Vec<f64>> {
        let bytes = self.take(n * 8, what)?;
        Ok(bytes.chunks_exact(8).map(|c| f64::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn bools(&mut self, n: usize, what: &str) -> Result<Vec<bool>> {
        let bytes = self.take(n, what)?;
        let mut out = Vec::with_capacity(n);
        for &b in bytes {
            match b {
                0 => out.push(false),
                1 => out.push(true),
                other => bail!("corrupt checkpoint: invalid boolean byte {other} in {what}"),
            }
        }
        Ok(out)
    }
}

fn enc_step(e: &mut Enc, s: &PathStep) -> Result<()> {
    e.f64(s.lambda);
    e.f64(s.r1);
    e.f64(s.r2);
    e.f64(s.screen_s);
    e.f64(s.solve_s);
    e.u64(s.active_features as u64);
    e.u64(s.iters as u64);
    e.f64(s.gap);
    e.u64(s.zeros as u64);
    e.u64(s.nonzeros as u64);
    e.u64(s.groups_rejected as u64);
    e.u64(s.features_rejected as u64);
    e.u64(s.dynamic_evicted as u64);
    e.u64(s.kkt_readmitted as u64);
    e.u8(s.budget_exhausted as u8);
    e.f64(s.certified_suboptimality);
    e.u64(s.ws_rounds as u64);
    e.u64(s.ws_final_size as u64);
    e.u64(s.layers.len() as u64);
    for l in &s.layers {
        e.u8(rule_id(l.rule)?);
        e.u8(match l.safety {
            Safety::Safe => 0,
            Safety::Heuristic => 1,
        });
        e.u64(l.groups as u64);
        e.u64(l.features as u64);
    }
    Ok(())
}

fn dec_step(d: &mut Dec<'_>) -> Result<PathStep> {
    let lambda = d.f64("step.lambda")?;
    let r1 = d.f64("step.r1")?;
    let r2 = d.f64("step.r2")?;
    let screen_s = d.f64("step.screen_s")?;
    let solve_s = d.f64("step.solve_s")?;
    let active_features = d.u64("step.active_features")? as usize;
    let iters = d.u64("step.iters")? as usize;
    let gap = d.f64("step.gap")?;
    let zeros = d.u64("step.zeros")? as usize;
    let nonzeros = d.u64("step.nonzeros")? as usize;
    let groups_rejected = d.u64("step.groups_rejected")? as usize;
    let features_rejected = d.u64("step.features_rejected")? as usize;
    let dynamic_evicted = d.u64("step.dynamic_evicted")? as usize;
    let kkt_readmitted = d.u64("step.kkt_readmitted")? as usize;
    let budget_exhausted = match d.u8("step.budget_exhausted")? {
        0 => false,
        1 => true,
        other => bail!("corrupt checkpoint: invalid budget flag {other}"),
    };
    let certified_suboptimality = d.f64("step.certified_suboptimality")?;
    let ws_rounds = d.u64("step.ws_rounds")? as usize;
    let ws_final_size = d.u64("step.ws_final_size")? as usize;
    let n_layers = d.u64("step.n_layers")? as usize;
    if n_layers > MAX_LAYERS {
        bail!("corrupt checkpoint: implausible layer count {n_layers}");
    }
    let mut layers = Vec::with_capacity(n_layers);
    for _ in 0..n_layers {
        let rule = rule_name(d.u8("layer.rule")?)?;
        let safety = match d.u8("layer.safety")? {
            0 => Safety::Safe,
            1 => Safety::Heuristic,
            other => bail!("corrupt checkpoint: invalid safety byte {other}"),
        };
        let groups = d.u64("layer.groups")? as usize;
        let features = d.u64("layer.features")? as usize;
        layers.push(LayerCount { rule, safety, groups, features });
    }
    Ok(PathStep {
        lambda,
        r1,
        r2,
        screen_s,
        solve_s,
        active_features,
        iters,
        gap,
        zeros,
        nonzeros,
        groups_rejected,
        features_rejected,
        layers,
        dynamic_evicted,
        kkt_readmitted,
        budget_exhausted,
        certified_suboptimality,
        ws_rounds,
        ws_final_size,
    })
}

// ---------------------------------------------------------------------------
// Save / load
// ---------------------------------------------------------------------------

/// Everything a resume needs, exactly as recorded.
struct LoadedState {
    scalar: Option<(usize, Vec<bool>, Option<f64>)>,
    group: Option<(usize, Vec<bool>, Vec<f64>)>,
    steps: Vec<PathStep>,
    betas: Vec<Vec<f32>>,
    screen_total_s: f64,
    solve_total_s: f64,
}

fn temp_sibling(path: &Path) -> PathBuf {
    let mut name = path.file_name().unwrap_or_else(|| "checkpoint".as_ref()).to_os_string();
    name.push(".tmp");
    path.with_file_name(name)
}

fn save_checkpoint(
    path: &Path,
    key: &CheckpointKey,
    snap: &EngineSnapshot,
    steps: &[PathStep],
    betas: &[Vec<f32>],
    screen_total_s: f64,
    solve_total_s: f64,
) -> Result<()> {
    debug_assert_eq!(steps.len(), betas.len());
    // The engine's live β is by construction the last per-step β (the sink
    // contract streams it after every scatter), so only the per-step βs are
    // stored and restore rehydrates the engine from the last one.
    debug_assert!(betas.last().is_some_and(|b| b == &snap.beta));
    let p = key.p as usize;
    let mut body = Enc { buf: Vec::new() };
    match &snap.scalar {
        Some((since, mask, value)) => {
            body.u8(1);
            body.u64(*since as u64);
            body.bools(mask);
            body.u8(value.is_some() as u8);
            body.f64(value.unwrap_or(0.0));
        }
        None => body.u8(0),
    }
    match &snap.group {
        Some((since, mask, values)) => {
            body.u8(1);
            body.u64(*since as u64);
            body.bools(mask);
            for &v in values {
                body.f64(v);
            }
        }
        None => body.u8(0),
    }
    for (s, b) in steps.iter().zip(betas) {
        debug_assert_eq!(b.len(), p);
        enc_step(&mut body, s)?;
        body.f32s(b);
    }

    let mut e = Enc { buf: Vec::with_capacity(128 + body.buf.len()) };
    e.buf.extend_from_slice(MAGIC);
    e.u32(VERSION);
    e.u64(key.n);
    e.u64(key.p);
    e.u64(key.n_groups);
    e.u64(key.n_lambda);
    e.u64(steps.len() as u64);
    e.f64(key.alpha);
    e.f64(key.lambda_min_ratio);
    e.f64(key.tol);
    e.f64(key.gap_inflation);
    e.f64(key.lambda_max);
    e.u8(key.solver);
    e.u8(key.screen);
    e.u8(key.flags);
    e.u8(snap.scalar.is_some() as u8);
    e.u8(snap.group.is_some() as u8);
    e.u8(0);
    e.u8(0);
    e.u8(0);
    e.u64(key.refresh);
    e.u64(key.max_iter);
    e.u64(key.ws_max_rounds);
    e.f64(key.ws_growth);
    e.f64(screen_total_s);
    e.f64(solve_total_s);
    e.u64(body.buf.len() as u64);
    e.buf.extend_from_slice(&body.buf);

    let tmp = temp_sibling(path);
    std::fs::write(&tmp, &e.buf).with_context(|| format!("writing checkpoint {tmp:?}"))?;
    std::fs::rename(&tmp, path)
        .with_context(|| format!("publishing checkpoint {tmp:?} -> {path:?}"))?;
    Ok(())
}

fn load_checkpoint(path: &Path, key: &CheckpointKey) -> Result<LoadedState> {
    let buf =
        std::fs::read(path).with_context(|| format!("reading checkpoint {path:?}"))?;
    let mut d = Dec { buf: &buf, pos: 0 };
    if d.take(8, "magic")? != MAGIC {
        bail!("{path:?}: not a TLFre checkpoint (bad magic)");
    }
    let version = d.u32("version")?;
    if version != VERSION {
        bail!("{path:?}: unsupported checkpoint version {version}");
    }
    let n = d.u64("n")?;
    let p = d.u64("p")?;
    let n_groups = d.u64("n_groups")?;
    let n_lambda = d.u64("n_lambda")?;
    let completed = d.u64("completed")? as usize;
    // Same plausibility envelope as the dataset loader: reject absurd
    // dimensions before they can size any allocation.
    if n == 0 || p == 0 || n_groups == 0 || n > 1 << 24 || p > 1 << 28 || n_groups > p {
        bail!("{path:?}: implausible checkpoint dimensions {n}×{p} ({n_groups} groups)");
    }
    let stored = CheckpointKey {
        n,
        p,
        n_groups,
        n_lambda,
        alpha: d.f64("alpha")?,
        lambda_min_ratio: d.f64("lambda_min_ratio")?,
        tol: d.f64("tol")?,
        gap_inflation: d.f64("gap_inflation")?,
        lambda_max: d.f64("lambda_max")?,
        solver: d.u8("solver")?,
        screen: d.u8("screen")?,
        flags: d.u8("flags")?,
        refresh: 0,
        max_iter: 0,
        ws_max_rounds: 0,
        ws_growth: 0.0,
    };
    let has_scalar = d.u8("has_scalar")? != 0;
    let has_group = d.u8("has_group")? != 0;
    d.take(3, "pad")?;
    let stored = CheckpointKey {
        refresh: d.u64("refresh")?,
        max_iter: d.u64("max_iter")?,
        ws_max_rounds: d.u64("ws_max_rounds")?,
        ws_growth: d.f64("ws_growth")?,
        ..stored
    };
    if !key.matches(&stored) {
        bail!(
            "{path:?}: checkpoint was written for a different problem or config \
             (stored {stored:?}, this run {key:?}); refusing to resume"
        );
    }
    if completed == 0 || completed > key.n_lambda as usize {
        bail!("{path:?}: corrupt checkpoint (completed={completed} of {})", key.n_lambda);
    }
    let screen_total_s = d.f64("screen_total_s")?;
    let solve_total_s = d.f64("solve_total_s")?;
    let payload_len = d.u64("payload_len")? as usize;
    if buf.len() - d.pos != payload_len {
        bail!(
            "{path:?}: corrupt checkpoint (payload length {} recorded, {} present)",
            payload_len,
            buf.len() - d.pos
        );
    }
    let p = p as usize;
    let scalar = if has_scalar {
        let since = d.u64("scalar.since")? as usize;
        let mask = d.bools(p, "scalar.mask")?;
        let has_value = d.u8("scalar.has_value")? != 0;
        let value = d.f64("scalar.value")?;
        Some((since, mask, has_value.then_some(value)))
    } else {
        None
    };
    let group = if has_group {
        let since = d.u64("group.since")? as usize;
        let mask = d.bools(p, "group.mask")?;
        let values = d.f64s(n_groups as usize, "group.values")?;
        Some((since, mask, values))
    } else {
        None
    };
    let mut steps = Vec::with_capacity(completed);
    let mut betas = Vec::with_capacity(completed);
    for _ in 0..completed {
        steps.push(dec_step(&mut d)?);
        betas.push(d.f32s(p, "step.beta")?);
    }
    if d.pos != buf.len() {
        bail!("{path:?}: corrupt checkpoint ({} trailing bytes)", buf.len() - d.pos);
    }
    Ok(LoadedState { scalar, group, steps, betas, screen_total_s, solve_total_s })
}

// ---------------------------------------------------------------------------
// The checkpointed driver loop
// ---------------------------------------------------------------------------

fn drive_checkpointed<E>(
    mut engine: E,
    key: CheckpointKey,
    opts: &CheckpointOptions,
) -> Result<(PathOutput, Vec<Vec<f32>>)>
where
    E: PathEngine<Step = PathStep> + Checkpointable,
{
    let every = opts.every.max(1);
    let lambda_max = engine.lambda_max();
    let (min_ratio, n_lambda) = engine.grid_shape();
    let grid = log_lambda_grid(lambda_max, min_ratio, n_lambda);

    let mut steps: Vec<PathStep>;
    let mut betas: Vec<Vec<f32>>;
    let mut screen_total: f64;
    let mut solve_total: f64;
    if opts.resume {
        let st = load_checkpoint(&opts.path, &key)
            .with_context(|| format!("resuming from {:?}", opts.path))?;
        let beta = st.betas.last().expect("load_checkpoint guarantees completed ≥ 1").clone();
        engine.restore(EngineSnapshot { beta, scalar: st.scalar, group: st.group });
        steps = st.steps;
        betas = st.betas;
        // Recorded prefix wall time plus this process's reconstruction
        // preamble (both were really spent; timings are not parity fields).
        screen_total = st.screen_total_s + engine.preamble_s();
        solve_total = st.solve_total_s;
    } else {
        steps = Vec::with_capacity(grid.len());
        betas = Vec::with_capacity(grid.len());
        let first = engine.zero_step(grid[0]);
        betas.push(engine.beta().to_vec());
        steps.push(first);
        screen_total = engine.preamble_s();
        solve_total = 0.0;
    }

    let deadline = engine.deadline();
    let mut truncated = false;
    let mut completed = steps.len();
    let mut lambda_bar = grid[completed - 1];
    while completed < grid.len() {
        if opts.stop_after.is_some_and(|k| completed >= k) {
            truncated = true;
            break;
        }
        if deadline_passed(deadline) {
            truncated = true;
            break;
        }
        let lambda = grid[completed];
        let es = engine.step(lambda, lambda_bar);
        screen_total += es.screen_s;
        solve_total += es.solve_s;
        steps.push(es.step);
        betas.push(engine.beta().to_vec());
        lambda_bar = lambda;
        completed += 1;
        if completed % every == 0 || completed == grid.len() {
            save_checkpoint(
                &opts.path,
                &key,
                &engine.snapshot(),
                &steps,
                &betas,
                screen_total,
                solve_total,
            )?;
        }
    }

    Ok((
        PathOutput {
            lambda_max,
            steps,
            screen_total_s: screen_total,
            solve_total_s: solve_total,
            truncated,
        },
        betas,
    ))
}

/// `run_tlfre_path` with kill-safe checkpointing: atomically saves a
/// resumable sidecar every [`CheckpointOptions::every`] completed grid
/// points, and with [`CheckpointOptions::resume`] continues a previously
/// killed run — bitwise identical, per-step stats and per-λ coefficients
/// both, to the run never having been interrupted (see the module docs
/// for what the sidecar captures and why that list is sufficient).
/// Returns the path output plus one full-space β per completed λ.
pub fn run_tlfre_path_checkpointed<M: DesignMatrix>(
    x: &M,
    y: &[f32],
    groups: &GroupStructure,
    cfg: &PathConfig,
    opts: &CheckpointOptions,
) -> Result<(PathOutput, Vec<Vec<f32>>)> {
    let engine = TlfreEngine::new(x, y, groups, cfg);
    let key =
        CheckpointKey::new(x.rows(), x.cols(), groups.n_groups(), cfg, engine.lambda_max());
    drive_checkpointed(engine, key, opts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::runner::SolveControls;
    use crate::data::synthetic::{generate_synthetic, SyntheticSpec};

    fn tmp(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("tlfre_ckpt_{}_{}", std::process::id(), name));
        p
    }

    fn cfg() -> PathConfig {
        PathConfig {
            alpha: 1.0,
            controls: SolveControls {
                n_lambda: 8,
                lambda_min_ratio: 0.05,
                tol: 1e-6,
                ..Default::default()
            },
            ..Default::default()
        }
    }

    #[test]
    fn full_run_then_resume_is_a_replay() {
        let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(25, 100, 10), 711);
        let path = tmp("replay.ck");
        let opts = CheckpointOptions { every: 3, ..CheckpointOptions::new(&path) };
        let (a, ab) =
            run_tlfre_path_checkpointed(&ds.x, &ds.y, &ds.groups, &cfg(), &opts).unwrap();
        assert!(!a.truncated);
        assert_eq!(a.steps.len(), 8);
        // Resuming a *completed* run replays the recorded path verbatim.
        let ropts = CheckpointOptions { resume: true, ..opts };
        let (b, bb) =
            run_tlfre_path_checkpointed(&ds.x, &ds.y, &ds.groups, &cfg(), &ropts).unwrap();
        assert_eq!(a.steps.len(), b.steps.len());
        for (x, y) in ab.iter().zip(&bb) {
            assert_eq!(x, y);
        }
        for (sa, sb) in a.steps.iter().zip(&b.steps) {
            assert_eq!(sa.lambda.to_bits(), sb.lambda.to_bits());
            assert_eq!(sa.gap.to_bits(), sb.gap.to_bits());
            assert_eq!(sa.nonzeros, sb.nonzeros);
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn stop_and_resume_matches_uninterrupted() {
        let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(25, 100, 10), 712);
        let reference = crate::coordinator::runner::run_tlfre_path_with_coefficients(
            &ds.x, &ds.y, &ds.groups, &cfg(),
        );
        let path = tmp("kill.ck");
        let opts = CheckpointOptions {
            every: 2,
            stop_after: Some(5),
            ..CheckpointOptions::new(&path)
        };
        let (first, _) =
            run_tlfre_path_checkpointed(&ds.x, &ds.y, &ds.groups, &cfg(), &opts).unwrap();
        assert!(first.truncated);
        assert_eq!(first.steps.len(), 5);
        // stop_after=5, every=2 → last save held 4 steps; the resume must
        // recompute the lost 5th bitwise identically and run to the end.
        let ropts = CheckpointOptions { resume: true, stop_after: None, ..opts };
        let (out, betas) =
            run_tlfre_path_checkpointed(&ds.x, &ds.y, &ds.groups, &cfg(), &ropts).unwrap();
        assert!(!out.truncated);
        assert_eq!(out.steps.len(), reference.0.steps.len());
        for (a, b) in betas.iter().zip(&reference.1) {
            assert_eq!(a, b, "resumed β diverged from uninterrupted run");
        }
        for (sa, sb) in out.steps.iter().zip(&reference.0.steps) {
            assert_eq!(sa.lambda.to_bits(), sb.lambda.to_bits());
            assert_eq!(sa.iters, sb.iters);
            assert_eq!(sa.gap.to_bits(), sb.gap.to_bits());
            assert_eq!(sa.active_features, sb.active_features);
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn config_mismatch_is_a_typed_error() {
        let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(20, 60, 6), 713);
        let path = tmp("mismatch.ck");
        let opts =
            CheckpointOptions { every: 2, stop_after: Some(4), ..CheckpointOptions::new(&path) };
        run_tlfre_path_checkpointed(&ds.x, &ds.y, &ds.groups, &cfg(), &opts).unwrap();
        let other = {
            let mut c = cfg();
            c.tol = 1e-4;
            c
        };
        let ropts = CheckpointOptions { resume: true, stop_after: None, ..opts };
        let err = run_tlfre_path_checkpointed(&ds.x, &ds.y, &ds.groups, &other, &ropts)
            .unwrap_err();
        assert!(
            format!("{err:#}").contains("different problem or config"),
            "unexpected error: {err:#}"
        );
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn working_set_knob_mismatch_is_a_typed_error() {
        // ws_growth/ws_max_rounds are fingerprint fields: under a ws
        // pipeline they steer the loose-round trajectory (and so every
        // warm start downstream), so a resume under different knobs must
        // be rejected, not silently continued.
        let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(20, 60, 6), 716);
        let base = {
            let mut c = cfg();
            c.screen = ScreenKind::TlfreWs;
            c
        };
        let path = tmp("ws_mismatch.ck");
        let opts =
            CheckpointOptions { every: 2, stop_after: Some(4), ..CheckpointOptions::new(&path) };
        run_tlfre_path_checkpointed(&ds.x, &ds.y, &ds.groups, &base, &opts).unwrap();
        let ropts = CheckpointOptions { resume: true, stop_after: None, ..opts };
        for mutate in [
            (&|c: &mut PathConfig| c.ws_growth = 3.0) as &dyn Fn(&mut PathConfig),
            &|c: &mut PathConfig| c.ws_max_rounds += 1,
        ] {
            let mut other = base.clone();
            mutate(&mut other);
            let err = run_tlfre_path_checkpointed(&ds.x, &ds.y, &ds.groups, &other, &ropts)
                .unwrap_err();
            assert!(
                format!("{err:#}").contains("different problem or config"),
                "unexpected error: {err:#}"
            );
        }
        // Unchanged knobs resume fine and run to completion.
        let (out, _) =
            run_tlfre_path_checkpointed(&ds.x, &ds.y, &ds.groups, &base, &ropts).unwrap();
        assert!(!out.truncated);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn truncated_file_is_a_typed_error() {
        let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(20, 60, 6), 714);
        let path = tmp("trunc.ck");
        let opts =
            CheckpointOptions { every: 2, stop_after: Some(4), ..CheckpointOptions::new(&path) };
        run_tlfre_path_checkpointed(&ds.x, &ds.y, &ds.groups, &cfg(), &opts).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 7]).unwrap();
        let ropts = CheckpointOptions { resume: true, stop_after: None, ..opts };
        let err = run_tlfre_path_checkpointed(&ds.x, &ds.y, &ds.groups, &cfg(), &ropts)
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("corrupt checkpoint"), "unexpected error: {msg}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn bad_magic_is_a_typed_error() {
        let path = tmp("magic.ck");
        std::fs::write(&path, b"NOTACKPTxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxxx").unwrap();
        let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(20, 60, 6), 715);
        let ropts = CheckpointOptions { resume: true, ..CheckpointOptions::new(&path) };
        let err = run_tlfre_path_checkpointed(&ds.x, &ds.y, &ds.groups, &cfg(), &ropts)
            .unwrap_err();
        assert!(format!("{err:#}").contains("bad magic"));
        std::fs::remove_file(&path).ok();
    }
}
