//! L3 coordinator: the pathwise regularization driver.
//!
//! This is the system the paper's evaluation actually runs: for each α,
//! solve SGL over a descending log-spaced λ grid (100 points from λmax to
//! 0.01·λmax), warm-starting each solve from the previous solution, with
//! TLFre screening interposed between path steps to shrink the design
//! matrix handed to the solver. The coordinator owns:
//!
//! * grid construction ([`path`]),
//! * **the streaming path driver** ([`driver`]) — the *single* per-λ loop
//!   (screen → reduce → refresh → solver dispatch → scatter) behind every
//!   pathwise workload, streaming each step to a caller-supplied
//!   [`PathSink`]. The runners and cross-validation are thin sink
//!   configurations over this one loop, so they cannot diverge (the
//!   pre-driver CV mirror once hardcoded FISTA while the runner dispatched
//!   on [`SolverKind`] — that class of bug is now structurally impossible),
//! * the screening ↔ solver interlock and reduced-problem extraction
//!   ([`runner`], [`reduce`]),
//! * the nonnegative-Lasso / DPC equivalent ([`dpc_runner`]),
//! * k-fold cross-validation ([`cv`]) — **one** screened walk per fold×α
//!   (a [`HoldoutSink`] folds β into held-out MSE as the path streams),
//!   sharded across the persistent worker pool with output bitwise
//!   identical to the serial sweep at every `TLFRE_THREADS`,
//! * per-step statistics — the paper's rejection ratios r₁/r₂, timings and
//!   speedups consumed by the bench harness,
//! * fault tolerance for long paths ([`checkpoint`]): kill-safe
//!   checkpoint/resume sidecars and wall-clock solve budgets
//!   ([`SolveControls::max_seconds`]) — on the SGL *and* DPC paths alike,
//! * the shared solve-control surface ([`SolveControls`]): one embedded
//!   struct holding the grid/tolerance/budget knobs for every pathwise
//!   config ([`PathConfig`], [`DpcPathConfig`], [`crate::config::Config`],
//!   the serve-mode wire schema), with one `Default`, one `validate()`
//!   and one JSON-parse path.
//!
//! ## Failure modes & recovery
//!
//! The path engine is built so that every failure an out-of-core path run
//! can realistically hit has a defined, tested outcome — a typed error or
//! a documented degradation, never silent garbage:
//!
//! * **Process killed mid-path** — run with a [`checkpoint`] sidecar;
//!   checkpoints are written atomically (temp sibling + rename), so a kill
//!   leaves either the previous complete checkpoint or none. Resume loses
//!   at most `every − 1` completed grid points and recomputes them
//!   **bitwise identically** (every kernel is deterministic at every
//!   worker count; the sidecar captures the engine's full mutable state —
//!   see `driver::EngineSnapshot`).
//! * **Run over time budget** — [`SolveControls::max_seconds`] derives
//!   one deadline at engine construction. Solvers check it at gap-check
//!   cadence and return their best-so-far iterate with `converged = false`
//!   plus the last measured duality gap; the driver refuses to start a
//!   step past the deadline. The output is a clean completed prefix
//!   ([`PathOutput::truncated`] / [`DpcPathOutput::truncated`]), each step
//!   carrying [`PathStep::budget_exhausted`] (SGL, with a finite
//!   [`PathStep::certified_suboptimality`] bound) or
//!   [`DpcStep::budget_exhausted`] (DPC).
//! * **Corrupt/mismatched checkpoint** — magic, version, dimensions and
//!   the full problem/config fingerprint are validated before any
//!   payload allocation; truncation or edits fail with a typed error
//!   naming the field.
//! * **Non-finite data** — [`crate::data::validate`] screens X/y for
//!   NaN/Inf, zero-norm columns and degenerate groups before any solve;
//!   if garbage still reaches a solver (e.g. poisoned mid-run), the gap
//!   check can never satisfy the stopping rule on a NaN, and the solvers
//!   abort the solve at the next check rather than iterate on it.
//! * **I/O faults in out-of-core backends** — see `linalg/README.md`
//!   ("Failure modes & recovery"): short reads and `EINTR` are retried,
//!   truncation and hard errors are loud.

pub mod checkpoint;
pub mod cv;
pub mod dpc_runner;
pub mod driver;
pub mod path;
pub mod reduce;
pub(crate) mod refresh;
pub mod runner;

pub use cv::{
    cross_validate, cross_validate_serial, cross_validate_with_workers, make_folds,
    path_coefficients, CvOutput, CvPoint,
};
pub use dpc_runner::{run_dpc_path, run_nonneg_baseline, DpcPathConfig, DpcPathOutput, DpcStep};
pub use driver::{
    drive_baseline_path, drive_dpc_path, drive_nonneg_baseline, drive_tlfre_path,
    drive_tlfre_path_with_pipeline, CoefficientSink, HoldoutSink, PathSink, PathTotals, StepSink,
};
pub use checkpoint::{run_tlfre_path_checkpointed, CheckpointOptions};
pub use path::{alpha_grid_from_angles, log_lambda_grid, PAPER_ALPHA_ANGLES};
pub use runner::{
    run_baseline_path, run_tlfre_path, run_tlfre_path_with_coefficients, PathConfig, PathOutput,
    PathStep, SolveControls, SolverKind,
};
