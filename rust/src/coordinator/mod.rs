//! L3 coordinator: the pathwise regularization driver.
//!
//! This is the system the paper's evaluation actually runs: for each α,
//! solve SGL over a descending log-spaced λ grid (100 points from λmax to
//! 0.01·λmax), warm-starting each solve from the previous solution, with
//! TLFre screening interposed between path steps to shrink the design
//! matrix handed to the solver. The coordinator owns:
//!
//! * grid construction ([`path`]),
//! * the screening ↔ solver interlock and reduced-problem extraction
//!   ([`runner`], [`reduce`]),
//! * the nonnegative-Lasso / DPC equivalent ([`dpc_runner`]),
//! * per-step statistics — the paper's rejection ratios r₁/r₂, timings and
//!   speedups consumed by the bench harness.

pub mod cv;
pub mod dpc_runner;
pub mod path;
pub mod reduce;
pub(crate) mod refresh;
pub mod runner;

pub use dpc_runner::{run_dpc_path, run_nonneg_baseline, DpcPathConfig, DpcPathOutput};
pub use path::{alpha_grid_from_angles, log_lambda_grid, PAPER_ALPHA_ANGLES};
pub use runner::{run_baseline_path, run_tlfre_path, PathConfig, PathOutput, PathStep, SolverKind};
