//! DPC pathwise runner for nonnegative Lasso (Section 6.2's protocol).
//!
//! Like the SGL runner, this is a thin façade since the streaming-driver
//! refactor: the per-λ loop lives in [`super::driver`] (the
//! `DpcEngine`/`DpcBaselineEngine` families) and the two entry points here
//! attach a [`super::driver::StepSink`] to it.

use super::driver::{drive_dpc_path, drive_nonneg_baseline, StepSink};
use crate::linalg::DesignMatrix;

/// Configuration for a DPC path run.
#[derive(Debug, Clone)]
pub struct DpcPathConfig {
    pub n_lambda: usize,
    pub lambda_min_ratio: f64,
    pub tol: f64,
    pub max_iter: usize,
    pub verify_safety: bool,
    /// See [`super::runner::PathConfig::gap_inflation`].
    pub gap_inflation: f64,
    /// Amortized per-view Lipschitz refresh for the reduced nonneg solves —
    /// same semantics (cadence, subset-validity fallback, screening-time
    /// accounting) as [`super::runner::PathConfig::lipschitz_refresh_every`].
    pub lipschitz_refresh_every: Option<usize>,
    /// In-solver dynamic GAP-safe screening for the reduced nonneg solves
    /// (the Theorem 22 sphere on the solver's shrinking duality gap; see
    /// [`crate::screening::gap_safe::GapSafeDynamicNonneg`]). The nonneg
    /// analogue of the SGL `tlfre+gap` pipeline's dynamic half; per-step
    /// evictions land in [`DpcStep::dynamic_evicted`]. CLI: `--dynamic`.
    pub dynamic_screening: bool,
}

impl Default for DpcPathConfig {
    fn default() -> Self {
        DpcPathConfig {
            n_lambda: 100,
            lambda_min_ratio: 0.01,
            tol: 1e-6,
            max_iter: 20_000,
            verify_safety: false,
            gap_inflation: 0.0,
            lipschitz_refresh_every: None,
            dynamic_screening: false,
        }
    }
}

impl DpcPathConfig {
    /// Validate the grid invariants (see
    /// [`super::runner::PathConfig::validate`]).
    pub fn validate(&self) {
        assert!(self.n_lambda >= 1, "n_lambda must be ≥ 1");
        assert!(
            self.lambda_min_ratio > 0.0 && self.lambda_min_ratio < 1.0,
            "lambda_min_ratio must be in (0, 1), got {}",
            self.lambda_min_ratio
        );
    }
}

/// Per-λ statistics of the DPC path.
#[derive(Debug, Clone)]
pub struct DpcStep {
    pub lambda: f64,
    /// Rejection ratio: screened features / actual inactive features.
    pub rejection: f64,
    pub screen_s: f64,
    pub solve_s: f64,
    pub active_features: usize,
    pub iters: usize,
    pub zeros: usize,
    /// Features evicted by in-solver dynamic GAP screening (0 unless
    /// [`DpcPathConfig::dynamic_screening`] is on).
    pub dynamic_evicted: usize,
}

/// Whole-path output.
#[derive(Debug, Clone)]
pub struct DpcPathOutput {
    pub lambda_max: f64,
    pub steps: Vec<DpcStep>,
    pub screen_total_s: f64,
    pub solve_total_s: f64,
}

impl DpcPathOutput {
    pub fn mean_rejection(&self) -> f64 {
        let xs: Vec<f64> =
            self.steps.iter().filter(|s| s.zeros > 0).map(|s| s.rejection).collect();
        if xs.is_empty() {
            0.0
        } else {
            xs.iter().sum::<f64>() / xs.len() as f64
        }
    }

    pub fn total_s(&self) -> f64 {
        self.screen_total_s + self.solve_total_s
    }
}

/// Run the DPC-screened nonnegative-Lasso path.
pub fn run_dpc_path<M: DesignMatrix>(x: &M, y: &[f32], cfg: &DpcPathConfig) -> DpcPathOutput {
    let mut sink = StepSink::new();
    let totals = drive_dpc_path(x, y, cfg, &mut sink);
    DpcPathOutput {
        lambda_max: totals.lambda_max,
        steps: sink.steps,
        screen_total_s: totals.screen_total_s,
        solve_total_s: totals.solve_total_s,
    }
}

/// The no-screening nonnegative-Lasso baseline path (Table 3's "solver").
pub fn run_nonneg_baseline<M: DesignMatrix>(x: &M, y: &[f32], cfg: &DpcPathConfig) -> DpcPathOutput {
    let mut sink = StepSink::new();
    let totals = drive_nonneg_baseline(x, y, cfg, &mut sink);
    DpcPathOutput {
        lambda_max: totals.lambda_max,
        steps: sink.steps,
        screen_total_s: totals.screen_total_s,
        solve_total_s: totals.solve_total_s,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::ops;
    use crate::linalg::DenseMatrix;
    use crate::util::Rng;

    fn nonneg_dataset(seed: u64, n: usize, p: usize) -> (DenseMatrix, Vec<f32>) {
        let mut rng = Rng::seed_from_u64(seed);
        let mut x = DenseMatrix::from_fn(n, p, |_, _| rng.gaussian().abs() as f32);
        x.normalize_cols();
        let picks = rng.sample_indices(p, p / 10 + 1);
        let mut y = vec![0.0f32; n];
        for &j in &picks {
            ops::axpy(rng.uniform_range(0.2, 1.0) as f32, x.col(j), &mut y);
        }
        (x, y)
    }

    fn cfg() -> DpcPathConfig {
        DpcPathConfig { n_lambda: 12, lambda_min_ratio: 0.05, tol: 1e-7, ..Default::default() }
    }

    #[test]
    fn dpc_path_matches_baseline_sparsity() {
        let (x, y) = nonneg_dataset(201, 25, 120);
        let a = run_dpc_path(&x, &y, &cfg());
        let b = run_nonneg_baseline(&x, &y, &cfg());
        for (sa, sb) in a.steps.iter().zip(&b.steps) {
            let diff = (sa.zeros as i64 - sb.zeros as i64).abs();
            assert!(diff <= 2, "λ={}: zeros {} vs {}", sa.lambda, sa.zeros, sb.zeros);
        }
    }

    #[test]
    fn dpc_path_safe() {
        let (x, y) = nonneg_dataset(202, 20, 80);
        let out = run_dpc_path(&x, &y, &DpcPathConfig { verify_safety: true, ..cfg() });
        assert!(out.mean_rejection() > 0.5, "rejection {}", out.mean_rejection());
    }

    #[test]
    fn refreshed_lipschitz_path_matches_default() {
        // The refresh changes step sizes, never optima: per-step sparsity
        // must track the cached-constant path within borderline coords.
        let (x, y) = nonneg_dataset(204, 25, 120);
        let a = run_dpc_path(&x, &y, &cfg());
        let b = run_dpc_path(
            &x,
            &y,
            &DpcPathConfig { lipschitz_refresh_every: Some(3), ..cfg() },
        );
        assert_eq!(a.steps.len(), b.steps.len());
        for (sa, sb) in a.steps.iter().zip(&b.steps) {
            let diff = (sa.zeros as i64 - sb.zeros as i64).abs();
            assert!(diff <= 2, "λ={}: zeros {} vs {}", sa.lambda, sa.zeros, sb.zeros);
        }
    }

    #[test]
    fn dynamic_screening_path_matches_default() {
        // In-solver evictions are GAP-safe: per-step sparsity must track
        // the static-only path within borderline coords, and evictions
        // must actually fire somewhere along the path.
        let (x, y) = nonneg_dataset(205, 25, 120);
        let a = run_dpc_path(&x, &y, &cfg());
        let b = run_dpc_path(&x, &y, &DpcPathConfig { dynamic_screening: true, ..cfg() });
        assert_eq!(a.steps.len(), b.steps.len());
        for (sa, sb) in a.steps.iter().zip(&b.steps) {
            let diff = (sa.zeros as i64 - sb.zeros as i64).abs();
            assert!(diff <= 2, "λ={}: zeros {} vs {}", sa.lambda, sa.zeros, sb.zeros);
        }
        assert!(
            b.steps.iter().any(|s| s.dynamic_evicted > 0),
            "dynamic screening never fired along the DPC path"
        );
        assert!(a.steps.iter().all(|s| s.dynamic_evicted == 0));
    }

    #[test]
    fn screening_reduces_work() {
        let (x, y) = nonneg_dataset(203, 25, 150);
        let out = run_dpc_path(&x, &y, &cfg());
        // The solver should essentially never see the full matrix.
        let max_active = out.steps.iter().map(|s| s.active_features).max().unwrap();
        assert!(max_active < 150, "screening never reduced the problem");
    }
}
