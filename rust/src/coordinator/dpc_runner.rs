//! DPC pathwise runner for nonnegative Lasso (Section 6.2's protocol).

use super::path::log_lambda_grid;
use super::refresh::ScalarRefresher;
use crate::linalg::ops;
use crate::linalg::{DesignMatrix, ScreenedView};
use crate::nonneg::{lambda_max, nonneg_lipschitz, solve_nonneg, NonnegOptions, NonnegProblem};
use crate::util::Timer;

/// Configuration for a DPC path run.
#[derive(Debug, Clone)]
pub struct DpcPathConfig {
    pub n_lambda: usize,
    pub lambda_min_ratio: f64,
    pub tol: f64,
    pub max_iter: usize,
    pub verify_safety: bool,
    /// See [`super::runner::PathConfig::gap_inflation`].
    pub gap_inflation: f64,
    /// Amortized per-view Lipschitz refresh for the reduced nonneg solves —
    /// same semantics (cadence, subset-validity fallback, screening-time
    /// accounting) as [`super::runner::PathConfig::lipschitz_refresh_every`].
    pub lipschitz_refresh_every: Option<usize>,
}

impl Default for DpcPathConfig {
    fn default() -> Self {
        DpcPathConfig {
            n_lambda: 100,
            lambda_min_ratio: 0.01,
            tol: 1e-6,
            max_iter: 20_000,
            verify_safety: false,
            gap_inflation: 0.0,
            lipschitz_refresh_every: None,
        }
    }
}

/// Per-λ statistics of the DPC path.
#[derive(Debug, Clone)]
pub struct DpcStep {
    pub lambda: f64,
    /// Rejection ratio: screened features / actual inactive features.
    pub rejection: f64,
    pub screen_s: f64,
    pub solve_s: f64,
    pub active_features: usize,
    pub iters: usize,
    pub zeros: usize,
}

/// Whole-path output.
#[derive(Debug, Clone)]
pub struct DpcPathOutput {
    pub lambda_max: f64,
    pub steps: Vec<DpcStep>,
    pub screen_total_s: f64,
    pub solve_total_s: f64,
}

impl DpcPathOutput {
    pub fn mean_rejection(&self) -> f64 {
        let xs: Vec<f64> =
            self.steps.iter().filter(|s| s.zeros > 0).map(|s| s.rejection).collect();
        if xs.is_empty() {
            0.0
        } else {
            xs.iter().sum::<f64>() / xs.len() as f64
        }
    }

    pub fn total_s(&self) -> f64 {
        self.screen_total_s + self.solve_total_s
    }
}

/// Run the DPC-screened nonnegative-Lasso path.
pub fn run_dpc_path<M: DesignMatrix>(x: &M, y: &[f32], cfg: &DpcPathConfig) -> DpcPathOutput {
    let prob = NonnegProblem::new(x, y);
    let p = x.cols();
    let n = x.rows();

    let mut screen_total = 0.0f64;
    let t = Timer::start();
    let col_norms = x.col_norms();
    let (lmax, argmax_col) = lambda_max(&prob);
    // Path-level Lipschitz cache (counted as screening time): `‖X‖₂²` is a
    // valid step bound for every survivor view (`σmax(X[:,S]) ≤ σmax(X)`),
    // so no reduced solve re-runs power iteration. `nonneg_lipschitz` is
    // the solver's own recipe — exact for the full problem.
    let path_lip = crate::nonneg::nonneg_lipschitz(x);
    screen_total += t.elapsed_s();

    let grid = log_lambda_grid(lmax, cfg.lambda_min_ratio, cfg.n_lambda);
    let mut steps = Vec::with_capacity(grid.len());
    steps.push(DpcStep {
        lambda: grid[0],
        rejection: 1.0,
        screen_s: 0.0,
        solve_s: 0.0,
        active_features: 0,
        iters: 0,
        zeros: p,
    });

    let mut beta = vec![0.0f32; p];
    let mut lambda_bar = lmax;
    let mut solve_total = 0.0f64;
    let mut resid = vec![0.0f32; n];

    // Amortized per-view refresh of the solver's step bound (subset-
    // validity rule in `coordinator::refresh`).
    let mut refresher =
        cfg.lipschitz_refresh_every.map(|k| ScalarRefresher::new(k, p));

    let mut corr = vec![0.0f32; p];
    for &lambda in &grid[1..] {
        // Feasibility-scaled dual point + gap-based radius inflation (see
        // the SGL runner for the rationale).
        let ts = Timer::start();
        x.residual(&beta, y, &mut resid);
        x.matvec_t(&resid, &mut corr);
        let (gap_raw, s_feas) =
            crate::nonneg::duality_gap(&prob, lambda_bar, &beta, &resid, &corr);
        let gap_bar = gap_raw * cfg.gap_inflation;
        let theta_bar: Vec<f32> =
            resid.iter().map(|&v| (v as f64 * s_feas / lambda_bar) as f32).collect();
        let out = crate::screening::dpc::dpc_screen_inexact(
            &prob, lambda, lambda_bar, &theta_bar, gap_bar, lmax, argmax_col, &col_norms,
        );
        let active: Vec<usize> = out.active_features();
        // Refresh inside the screening timer: the amortized power
        // iteration is spectral preamble work, attributed to screen_s so
        // solve-time comparisons against the cached mode stay fair.
        let step_lip = match (&mut refresher, active.is_empty()) {
            (Some(rf), false) => rf.step(&active, path_lip, || {
                nonneg_lipschitz(&ScreenedView::new(x, active.clone()))
            }),
            _ => path_lip,
        };
        let screen_s = ts.elapsed_s();
        screen_total += screen_s;

        let ts = Timer::start();
        let (iters, active_n) = if active.is_empty() {
            beta.fill(0.0);
            (0usize, 0usize)
        } else {
            // Zero-copy survivor view — no per-λ column gather.
            let xr = ScreenedView::new(x, active.clone());
            let rp = NonnegProblem::new(&xr, y);
            let warm: Vec<f32> = active.iter().map(|&j| beta[j]).collect();
            let res = solve_nonneg(
                &rp,
                lambda,
                Some(&warm),
                &NonnegOptions {
                    tol: cfg.tol,
                    max_iter: cfg.max_iter,
                    lipschitz: Some(step_lip),
                    ..Default::default()
                },
            );
            beta.fill(0.0);
            for (k, &j) in active.iter().enumerate() {
                beta[j] = res.beta[k];
            }
            (res.iters, active.len())
        };
        let solve_s = ts.elapsed_s();
        solve_total += solve_s;

        if cfg.verify_safety {
            // Exact cached constant for the full problem.
            let full = solve_nonneg(
                &prob,
                lambda,
                None,
                &NonnegOptions {
                    tol: cfg.tol,
                    max_iter: cfg.max_iter,
                    lipschitz: Some(path_lip),
                    ..Default::default()
                },
            );
            for j in 0..p {
                if !out.feature_kept[j] {
                    assert!(
                        full.beta[j].abs() < 1e-4,
                        "DPC SAFETY VIOLATION at λ={lambda}: feature {j} β={}",
                        full.beta[j]
                    );
                }
            }
        }

        let zeros = ops::count_zeros(&beta);
        steps.push(DpcStep {
            lambda,
            rejection: out.rejected as f64 / zeros.max(1) as f64,
            screen_s,
            solve_s,
            active_features: active_n,
            iters,
            zeros,
        });
        lambda_bar = lambda;
    }

    DpcPathOutput { lambda_max: lmax, steps, screen_total_s: screen_total, solve_total_s: solve_total }
}

/// The no-screening nonnegative-Lasso baseline path (Table 3's "solver").
pub fn run_nonneg_baseline<M: DesignMatrix>(x: &M, y: &[f32], cfg: &DpcPathConfig) -> DpcPathOutput {
    let prob = NonnegProblem::new(x, y);
    let p = x.cols();
    let (lmax, _) = lambda_max(&prob);
    let grid = log_lambda_grid(lmax, cfg.lambda_min_ratio, cfg.n_lambda);

    // The solver's canonical step-bound recipe (2% from-below inflation).
    let lip = crate::nonneg::nonneg_lipschitz(x);

    let mut steps = Vec::with_capacity(grid.len());
    steps.push(DpcStep {
        lambda: grid[0],
        rejection: 0.0,
        screen_s: 0.0,
        solve_s: 0.0,
        active_features: p,
        iters: 0,
        zeros: p,
    });
    let mut beta = vec![0.0f32; p];
    let mut solve_total = 0.0f64;
    for &lambda in &grid[1..] {
        let ts = Timer::start();
        let res = solve_nonneg(
            &prob,
            lambda,
            Some(&beta),
            &NonnegOptions {
                tol: cfg.tol,
                max_iter: cfg.max_iter,
                lipschitz: Some(lip),
                ..Default::default()
            },
        );
        let solve_s = ts.elapsed_s();
        solve_total += solve_s;
        beta = res.beta;
        steps.push(DpcStep {
            lambda,
            rejection: 0.0,
            screen_s: 0.0,
            solve_s,
            active_features: p,
            iters: res.iters,
            zeros: ops::count_zeros(&beta),
        });
    }
    DpcPathOutput { lambda_max: lmax, steps, screen_total_s: 0.0, solve_total_s: solve_total }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::DenseMatrix;
    use crate::util::Rng;

    fn nonneg_dataset(seed: u64, n: usize, p: usize) -> (DenseMatrix, Vec<f32>) {
        let mut rng = Rng::seed_from_u64(seed);
        let mut x = DenseMatrix::from_fn(n, p, |_, _| rng.gaussian().abs() as f32);
        x.normalize_cols();
        let picks = rng.sample_indices(p, p / 10 + 1);
        let mut y = vec![0.0f32; n];
        for &j in &picks {
            ops::axpy(rng.uniform_range(0.2, 1.0) as f32, x.col(j), &mut y);
        }
        (x, y)
    }

    fn cfg() -> DpcPathConfig {
        DpcPathConfig { n_lambda: 12, lambda_min_ratio: 0.05, tol: 1e-7, ..Default::default() }
    }

    #[test]
    fn dpc_path_matches_baseline_sparsity() {
        let (x, y) = nonneg_dataset(201, 25, 120);
        let a = run_dpc_path(&x, &y, &cfg());
        let b = run_nonneg_baseline(&x, &y, &cfg());
        for (sa, sb) in a.steps.iter().zip(&b.steps) {
            let diff = (sa.zeros as i64 - sb.zeros as i64).abs();
            assert!(diff <= 2, "λ={}: zeros {} vs {}", sa.lambda, sa.zeros, sb.zeros);
        }
    }

    #[test]
    fn dpc_path_safe() {
        let (x, y) = nonneg_dataset(202, 20, 80);
        let out = run_dpc_path(&x, &y, &DpcPathConfig { verify_safety: true, ..cfg() });
        assert!(out.mean_rejection() > 0.5, "rejection {}", out.mean_rejection());
    }

    #[test]
    fn refreshed_lipschitz_path_matches_default() {
        // The refresh changes step sizes, never optima: per-step sparsity
        // must track the cached-constant path within borderline coords.
        let (x, y) = nonneg_dataset(204, 25, 120);
        let a = run_dpc_path(&x, &y, &cfg());
        let b = run_dpc_path(
            &x,
            &y,
            &DpcPathConfig { lipschitz_refresh_every: Some(3), ..cfg() },
        );
        assert_eq!(a.steps.len(), b.steps.len());
        for (sa, sb) in a.steps.iter().zip(&b.steps) {
            let diff = (sa.zeros as i64 - sb.zeros as i64).abs();
            assert!(diff <= 2, "λ={}: zeros {} vs {}", sa.lambda, sa.zeros, sb.zeros);
        }
    }

    #[test]
    fn screening_reduces_work() {
        let (x, y) = nonneg_dataset(203, 25, 150);
        let out = run_dpc_path(&x, &y, &cfg());
        // The solver should essentially never see the full matrix.
        let max_active = out.steps.iter().map(|s| s.active_features).max().unwrap();
        assert!(max_active < 150, "screening never reduced the problem");
    }
}
