//! DPC pathwise runner for nonnegative Lasso (Section 6.2's protocol).
//!
//! Like the SGL runner, this is a thin façade since the streaming-driver
//! refactor: the per-λ loop lives in [`super::driver`] (the
//! `DpcEngine`/`DpcBaselineEngine` families) and the two entry points here
//! attach a [`super::driver::StepSink`] to it.

use super::driver::{drive_dpc_path, drive_nonneg_baseline, StepSink};
use super::runner::SolveControls;
use crate::linalg::DesignMatrix;

/// Configuration for a DPC path run.
///
/// The solve-control knobs (grid shape, tolerances, budgets, safety
/// verification, Lipschitz refresh) are the same [`SolveControls`] struct
/// the SGL [`super::runner::PathConfig`] embeds — one definition, one
/// `Default`, one `validate()`, one JSON-parse path. `DpcPathConfig`
/// derefs to it, so `cfg.tol` / `cfg.max_seconds` read and write through.
#[derive(Debug, Clone)]
pub struct DpcPathConfig {
    /// The shared solve-control knobs — reachable directly via `Deref`.
    pub controls: SolveControls,
    /// In-solver dynamic GAP-safe screening for the reduced nonneg solves
    /// (the Theorem 22 sphere on the solver's shrinking duality gap; see
    /// [`crate::screening::gap_safe::GapSafeDynamicNonneg`]). The nonneg
    /// analogue of the SGL `tlfre+gap` pipeline's dynamic half; per-step
    /// evictions land in [`DpcStep::dynamic_evicted`]. CLI: `--dynamic`.
    pub dynamic_screening: bool,
}

impl std::ops::Deref for DpcPathConfig {
    type Target = SolveControls;
    fn deref(&self) -> &SolveControls {
        &self.controls
    }
}

impl std::ops::DerefMut for DpcPathConfig {
    fn deref_mut(&mut self) -> &mut SolveControls {
        &mut self.controls
    }
}

impl Default for DpcPathConfig {
    fn default() -> Self {
        DpcPathConfig { controls: SolveControls::default(), dynamic_screening: false }
    }
}

impl DpcPathConfig {
    /// Validate the shared control invariants
    /// ([`SolveControls::validate`]).
    pub fn validate(&self) {
        self.controls.validate();
    }
}

/// Per-λ statistics of the DPC path.
#[derive(Debug, Clone)]
pub struct DpcStep {
    pub lambda: f64,
    /// Rejection ratio: screened features / actual inactive features.
    pub rejection: f64,
    pub screen_s: f64,
    pub solve_s: f64,
    pub active_features: usize,
    pub iters: usize,
    pub zeros: usize,
    /// Features evicted by in-solver dynamic GAP screening (0 unless
    /// [`DpcPathConfig::dynamic_screening`] is on).
    pub dynamic_evicted: usize,
    /// True when this step's solve stopped on a budget — the iteration cap
    /// or the [`SolveControls::max_seconds`] deadline — instead of
    /// reaching the gap tolerance (same contract as the SGL path's
    /// `PathStep::budget_exhausted`).
    pub budget_exhausted: bool,
}

/// Whole-path output.
#[derive(Debug, Clone)]
pub struct DpcPathOutput {
    pub lambda_max: f64,
    pub steps: Vec<DpcStep>,
    pub screen_total_s: f64,
    pub solve_total_s: f64,
    /// True when the [`SolveControls::max_seconds`] wall-clock budget
    /// stopped the grid walk early: `steps` is then a clean completed
    /// prefix of the grid (same contract as the SGL path's
    /// `PathOutput::truncated`).
    pub truncated: bool,
}

impl DpcPathOutput {
    pub fn mean_rejection(&self) -> f64 {
        let xs: Vec<f64> =
            self.steps.iter().filter(|s| s.zeros > 0).map(|s| s.rejection).collect();
        if xs.is_empty() {
            0.0
        } else {
            xs.iter().sum::<f64>() / xs.len() as f64
        }
    }

    pub fn total_s(&self) -> f64 {
        self.screen_total_s + self.solve_total_s
    }
}

/// Run the DPC-screened nonnegative-Lasso path.
pub fn run_dpc_path<M: DesignMatrix>(x: &M, y: &[f32], cfg: &DpcPathConfig) -> DpcPathOutput {
    let mut sink = StepSink::new();
    let totals = drive_dpc_path(x, y, cfg, &mut sink);
    DpcPathOutput {
        lambda_max: totals.lambda_max,
        steps: sink.steps,
        screen_total_s: totals.screen_total_s,
        solve_total_s: totals.solve_total_s,
        truncated: totals.truncated,
    }
}

/// The no-screening nonnegative-Lasso baseline path (Table 3's "solver").
pub fn run_nonneg_baseline<M: DesignMatrix>(x: &M, y: &[f32], cfg: &DpcPathConfig) -> DpcPathOutput {
    let mut sink = StepSink::new();
    let totals = drive_nonneg_baseline(x, y, cfg, &mut sink);
    DpcPathOutput {
        lambda_max: totals.lambda_max,
        steps: sink.steps,
        screen_total_s: totals.screen_total_s,
        solve_total_s: totals.solve_total_s,
        truncated: totals.truncated,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::ops;
    use crate::linalg::DenseMatrix;
    use crate::util::Rng;

    fn nonneg_dataset(seed: u64, n: usize, p: usize) -> (DenseMatrix, Vec<f32>) {
        let mut rng = Rng::seed_from_u64(seed);
        let mut x = DenseMatrix::from_fn(n, p, |_, _| rng.gaussian().abs() as f32);
        x.normalize_cols();
        let picks = rng.sample_indices(p, p / 10 + 1);
        let mut y = vec![0.0f32; n];
        for &j in &picks {
            ops::axpy(rng.uniform_range(0.2, 1.0) as f32, x.col(j), &mut y);
        }
        (x, y)
    }

    fn cfg() -> DpcPathConfig {
        DpcPathConfig {
            controls: SolveControls {
                n_lambda: 12,
                lambda_min_ratio: 0.05,
                tol: 1e-7,
                ..Default::default()
            },
            ..Default::default()
        }
    }

    #[test]
    fn dpc_path_matches_baseline_sparsity() {
        let (x, y) = nonneg_dataset(201, 25, 120);
        let a = run_dpc_path(&x, &y, &cfg());
        let b = run_nonneg_baseline(&x, &y, &cfg());
        for (sa, sb) in a.steps.iter().zip(&b.steps) {
            let diff = (sa.zeros as i64 - sb.zeros as i64).abs();
            assert!(diff <= 2, "λ={}: zeros {} vs {}", sa.lambda, sa.zeros, sb.zeros);
        }
    }

    #[test]
    fn dpc_path_safe() {
        let (x, y) = nonneg_dataset(202, 20, 80);
        let verified = {
            let mut c = cfg();
            c.verify_safety = true;
            c
        };
        let out = run_dpc_path(&x, &y, &verified);
        assert!(out.mean_rejection() > 0.5, "rejection {}", out.mean_rejection());
    }

    #[test]
    fn refreshed_lipschitz_path_matches_default() {
        // The refresh changes step sizes, never optima: per-step sparsity
        // must track the cached-constant path within borderline coords.
        let (x, y) = nonneg_dataset(204, 25, 120);
        let a = run_dpc_path(&x, &y, &cfg());
        let refreshed = {
            let mut c = cfg();
            c.lipschitz_refresh_every = Some(3);
            c
        };
        let b = run_dpc_path(&x, &y, &refreshed);
        assert_eq!(a.steps.len(), b.steps.len());
        for (sa, sb) in a.steps.iter().zip(&b.steps) {
            let diff = (sa.zeros as i64 - sb.zeros as i64).abs();
            assert!(diff <= 2, "λ={}: zeros {} vs {}", sa.lambda, sa.zeros, sb.zeros);
        }
    }

    #[test]
    fn dynamic_screening_path_matches_default() {
        // In-solver evictions are GAP-safe: per-step sparsity must track
        // the static-only path within borderline coords, and evictions
        // must actually fire somewhere along the path.
        let (x, y) = nonneg_dataset(205, 25, 120);
        let a = run_dpc_path(&x, &y, &cfg());
        let b = run_dpc_path(&x, &y, &DpcPathConfig { dynamic_screening: true, ..cfg() });
        assert_eq!(a.steps.len(), b.steps.len());
        for (sa, sb) in a.steps.iter().zip(&b.steps) {
            let diff = (sa.zeros as i64 - sb.zeros as i64).abs();
            assert!(diff <= 2, "λ={}: zeros {} vs {}", sa.lambda, sa.zeros, sb.zeros);
        }
        assert!(
            b.steps.iter().any(|s| s.dynamic_evicted > 0),
            "dynamic screening never fired along the DPC path"
        );
        assert!(a.steps.iter().all(|s| s.dynamic_evicted == 0));
    }

    #[test]
    fn screening_reduces_work() {
        let (x, y) = nonneg_dataset(203, 25, 150);
        let out = run_dpc_path(&x, &y, &cfg());
        // The solver should essentially never see the full matrix.
        let max_active = out.steps.iter().map(|s| s.active_features).max().unwrap();
        assert!(max_active < 150, "screening never reduced the problem");
    }
}
