//! Experiment configuration.
//!
//! JSON-backed (via [`crate::util::json`]; serde is unavailable offline) so
//! experiment definitions can be versioned and passed to the CLI with
//! `--config`. All fields have defaults — an empty object is a valid
//! config — and unknown keys are rejected to catch typos.

use crate::bail;
use crate::coordinator::runner::{SolveControls, SolverKind};
use crate::error::{Context, Result};
use crate::screening::rule::ScreenKind;
use crate::util::json::Json;

/// The **single** JSON-parse path for the shared solve-control knobs.
///
/// Every JSON surface that carries solve controls — the `--config` file
/// parsed by [`Config::from_json`] and the serve-mode wire schema parsed
/// by [`crate::server::api`] — routes unmatched keys through
/// [`SolveControls::apply_json_key`], so key names, per-key validation,
/// and error wording cannot drift between the CLI and the server.
impl SolveControls {
    /// Apply one JSON key to these controls. Returns `Ok(true)` when the
    /// key named a control field (value parsed, validated and stored),
    /// `Ok(false)` when the key is not a control (callers decide whether
    /// that is a typed unknown-key error), and `Err` on a bad value.
    pub fn apply_json_key(&mut self, key: &str, val: &Json) -> Result<bool> {
        match key {
            "n_lambda" => {
                self.n_lambda =
                    val.as_usize().context("n_lambda must be a nonnegative integer")?;
                // n_lambda == 1 is the legal single-point grid (λmax
                // alone); only an empty grid is rejected (matches
                // SolveControls::validate).
                if self.n_lambda < 1 {
                    bail!("n_lambda must be ≥ 1");
                }
            }
            "lambda_min_ratio" => {
                self.lambda_min_ratio =
                    val.as_f64().context("lambda_min_ratio must be a number")?;
                if !(self.lambda_min_ratio > 0.0 && self.lambda_min_ratio < 1.0) {
                    bail!("lambda_min_ratio must be in (0, 1)");
                }
            }
            "tol" => self.tol = val.as_f64().context("tol must be a number")?,
            "max_iter" => {
                self.max_iter = val.as_usize().context("max_iter must be an integer")?;
            }
            "verify_safety" => {
                self.verify_safety =
                    val.as_bool().context("verify_safety must be a boolean")?;
            }
            "gap_inflation" => {
                self.gap_inflation = val.as_f64().context("gap_inflation must be a number")?;
                if !(self.gap_inflation >= 0.0 && self.gap_inflation.is_finite()) {
                    bail!("gap_inflation must be a finite number ≥ 0");
                }
            }
            "lipschitz_refresh_every" => {
                // null = cached mode (the default); K ≥ 1 = refresh cadence.
                self.lipschitz_refresh_every = match val {
                    Json::Null => None,
                    other => {
                        let k = other.as_usize().context(
                            "lipschitz_refresh_every must be a positive integer or null",
                        )?;
                        if k == 0 {
                            bail!("lipschitz_refresh_every must be ≥ 1 (or null to disable)");
                        }
                        Some(k)
                    }
                };
            }
            "max_seconds" => {
                // null = no budget (the default); otherwise a positive
                // finite wall-clock budget in seconds.
                self.max_seconds = match val {
                    Json::Null => None,
                    other => {
                        let s = other
                            .as_f64()
                            .context("max_seconds must be a positive number or null")?;
                        if !(s > 0.0 && s.is_finite()) {
                            bail!("max_seconds must be positive and finite (or null)");
                        }
                        Some(s)
                    }
                };
            }
            "ws_max_rounds" => {
                self.ws_max_rounds =
                    val.as_usize().context("ws_max_rounds must be an integer")?;
                if self.ws_max_rounds < 2 {
                    bail!("ws_max_rounds must be ≥ 2");
                }
            }
            "ws_growth" => {
                self.ws_growth = val.as_f64().context("ws_growth must be a number")?;
                if !(self.ws_growth > 1.0 && self.ws_growth.is_finite()) {
                    bail!("ws_growth must be a finite factor > 1");
                }
            }
            _ => return Ok(false),
        }
        Ok(true)
    }

    /// Emit the control fields onto a JSON object — the inverse of
    /// [`Self::apply_json_key`], shared by [`Config::to_json`] and the
    /// serve-mode response/manifest writers.
    pub fn emit_json(&self, obj: Json) -> Json {
        obj.set("n_lambda", self.n_lambda)
            .set("lambda_min_ratio", self.lambda_min_ratio)
            .set("tol", self.tol)
            .set("max_iter", self.max_iter)
            .set("verify_safety", self.verify_safety)
            .set("gap_inflation", self.gap_inflation)
            .set(
                "lipschitz_refresh_every",
                match self.lipschitz_refresh_every {
                    Some(k) => Json::from(k),
                    None => Json::Null,
                },
            )
            .set(
                "max_seconds",
                match self.max_seconds {
                    Some(s) => Json::from(s),
                    None => Json::Null,
                },
            )
            .set("ws_max_rounds", self.ws_max_rounds)
            .set("ws_growth", self.ws_growth)
    }
}

/// Top-level experiment configuration.
///
/// The shared solve-control knobs (grid shape, tolerances, budgets) live
/// in the embedded [`SolveControls`]; `Config` derefs to it, so
/// `cfg.n_lambda` / `cfg.tol` read and write through. Defaults are
/// single-sourced in [`SolveControls::default`] — the CLI, the JSON
/// config file, and the serve-mode wire schema cannot drift.
#[derive(Debug, Clone, PartialEq)]
pub struct Config {
    /// α values (problem (3)); default = paper's seven tan(ψ) values.
    pub alphas: Vec<f64>,
    /// Solver: "fista" | "bcd".
    pub solver: SolverKind,
    /// Dataset seed.
    pub seed: u64,
    /// Feature-dimension scale for simulated real data sets.
    pub scale: f64,
    /// Fold count for the `cv` command / [`crate::coordinator::cv`].
    pub k_folds: usize,
    /// Pool-parallel red-black BCD group sweeps (no effect under FISTA).
    /// See [`crate::coordinator::runner::PathConfig::parallel_bcd_groups`].
    pub parallel_bcd_groups: bool,
    /// Screening pipeline: "tlfre" (default) | "tlfre+gap" | "gap" |
    /// "strong+kkt" | "ws" | "tlfre+ws" | "ws+gap" | "none". See
    /// [`crate::coordinator::runner::PathConfig::screen`].
    pub screen: ScreenKind,
    /// The shared solve-control knobs — reachable directly via `Deref`.
    pub controls: SolveControls,
}

impl std::ops::Deref for Config {
    type Target = SolveControls;
    fn deref(&self) -> &SolveControls {
        &self.controls
    }
}

impl std::ops::DerefMut for Config {
    fn deref_mut(&mut self) -> &mut SolveControls {
        &mut self.controls
    }
}

impl Default for Config {
    fn default() -> Self {
        Config {
            alphas: crate::coordinator::path::alpha_grid_from_angles(
                &crate::coordinator::path::PAPER_ALPHA_ANGLES,
            ),
            solver: SolverKind::Fista,
            seed: 42,
            scale: 0.1,
            k_folds: 5,
            parallel_bcd_groups: false,
            screen: ScreenKind::Tlfre,
            controls: SolveControls::default(),
        }
    }
}

impl Config {
    /// Parse from JSON text; unknown keys are errors.
    pub fn from_json(text: &str) -> Result<Config> {
        let v = Json::parse(text).context("config is not valid JSON")?;
        let obj = v.as_obj().context("config must be a JSON object")?;
        let mut cfg = Config::default();
        for (k, val) in obj {
            match k.as_str() {
                "alphas" => {
                    let arr = val.as_arr().context("alphas must be an array")?;
                    cfg.alphas = arr
                        .iter()
                        .map(|x| x.as_f64().context("alpha must be a number"))
                        .collect::<Result<_>>()?;
                    if cfg.alphas.is_empty() {
                        bail!("alphas must be non-empty");
                    }
                    if cfg.alphas.iter().any(|&a| a <= 0.0) {
                        bail!("alphas must be positive");
                    }
                }
                "solver" => {
                    cfg.solver = val
                        .as_str()
                        .and_then(SolverKind::parse)
                        .with_context(|| {
                            format!("unknown solver {val:?} (want \"fista\" or \"bcd\")")
                        })?;
                }
                "parallel_bcd_groups" => {
                    cfg.parallel_bcd_groups =
                        val.as_bool().context("parallel_bcd_groups must be a boolean")?;
                }
                "screen" => {
                    let s = val.as_str().context("screen must be a string")?;
                    cfg.screen = ScreenKind::parse(s).with_context(|| {
                        format!(
                            "unknown screen pipeline '{s}' \
                             (tlfre|tlfre+gap|gap|strong+kkt|ws|tlfre+ws|ws+gap|none)"
                        )
                    })?;
                }
                "seed" => cfg.seed = val.as_usize().context("seed must be an integer")? as u64,
                "scale" => {
                    cfg.scale = val.as_f64().context("scale must be a number")?;
                    if !(cfg.scale > 0.0 && cfg.scale <= 1.0) {
                        bail!("scale must be in (0, 1]");
                    }
                }
                "k_folds" => {
                    cfg.k_folds = val.as_usize().context("k_folds must be an integer")?;
                    if cfg.k_folds < 2 {
                        bail!("k_folds must be ≥ 2");
                    }
                }
                other => {
                    if !cfg.controls.apply_json_key(other, val)? {
                        bail!("unknown config key '{other}'");
                    }
                }
            }
        }
        Ok(cfg)
    }

    /// Load from a file path.
    pub fn from_file(path: &std::path::Path) -> Result<Config> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading config {path:?}"))?;
        Self::from_json(&text)
    }

    /// Serialize back to JSON (for run manifests). Control fields are
    /// emitted by [`SolveControls::emit_json`] — the same single source as
    /// parsing, so the roundtrip covers every key.
    pub fn to_json(&self) -> Json {
        let obj = Json::obj()
            .set("alphas", self.alphas.clone())
            .set("solver", self.solver.as_str())
            .set("seed", self.seed as usize)
            .set("scale", self.scale)
            .set("k_folds", self.k_folds)
            .set("parallel_bcd_groups", self.parallel_bcd_groups)
            .set("screen", self.screen.as_str());
        self.controls.emit_json(obj)
    }

    /// Per-α path configuration: the embedded controls verbatim plus the
    /// Config-level solver/screen/parallelism choices.
    pub fn path_config(&self, alpha: f64) -> crate::coordinator::runner::PathConfig {
        crate::coordinator::runner::PathConfig {
            alpha,
            solver: self.solver,
            materialize_reduced: false,
            exact_view_lipschitz: false,
            parallel_bcd_groups: self.parallel_bcd_groups,
            screen: self.screen,
            controls: self.controls,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_object_is_default() {
        let cfg = Config::from_json("{}").unwrap();
        assert_eq!(cfg, Config::default());
        assert_eq!(cfg.alphas.len(), 7);
    }

    #[test]
    fn roundtrip_through_json() {
        let mut cfg = Config::default();
        cfg.n_lambda = 50;
        cfg.solver = SolverKind::Bcd;
        cfg.tol = 1e-8;
        cfg.lipschitz_refresh_every = Some(5);
        cfg.parallel_bcd_groups = true;
        cfg.ws_max_rounds = 7;
        cfg.ws_growth = 1.5;
        let text = cfg.to_json().to_string_pretty();
        let back = Config::from_json(&text).unwrap();
        assert_eq!(cfg, back);
    }

    #[test]
    fn rejects_unknown_keys_and_bad_values() {
        assert!(Config::from_json(r#"{"n_lamda": 10}"#).is_err()); // typo
        assert!(Config::from_json(r#"{"solver": "adam"}"#).is_err());
        assert!(Config::from_json(r#"{"lambda_min_ratio": 2.0}"#).is_err());
        assert!(Config::from_json(r#"{"alphas": [1.0, -2.0]}"#).is_err());
        assert!(Config::from_json(r#"{"alphas": []}"#).is_err());
        assert!(Config::from_json(r#"{"n_lambda": 0}"#).is_err());
        assert!(Config::from_json(r#"{"scale": 0.0}"#).is_err());
        assert!(Config::from_json(r#"{"k_folds": 1}"#).is_err());
        assert!(Config::from_json(r#"{"lipschitz_refresh_every": 0}"#).is_err());
        assert!(Config::from_json(r#"{"lipschitz_refresh_every": "often"}"#).is_err());
        assert!(Config::from_json(r#"{"parallel_bcd_groups": 1}"#).is_err());
        assert!(Config::from_json(r#"{"screen": "magic"}"#).is_err());
        assert!(Config::from_json(r#"{"screen": 3}"#).is_err());
        assert!(Config::from_json(r#"{"ws_max_rounds": 1}"#).is_err());
        assert!(Config::from_json(r#"{"ws_growth": 1.0}"#).is_err());
        assert!(Config::from_json(r#"{"ws_growth": "fast"}"#).is_err());
        assert!(Config::from_json("not json").is_err());
    }

    #[test]
    fn screen_key_parses_and_threads_into_path_config() {
        for (text, kind) in [
            (r#"{"screen": "tlfre"}"#, ScreenKind::Tlfre),
            (r#"{"screen": "tlfre+gap"}"#, ScreenKind::TlfreGap),
            (r#"{"screen": "gap"}"#, ScreenKind::Gap),
            (r#"{"screen": "strong+kkt"}"#, ScreenKind::StrongKkt),
            (r#"{"screen": "ws"}"#, ScreenKind::Ws),
            (r#"{"screen": "tlfre+ws"}"#, ScreenKind::TlfreWs),
            (r#"{"screen": "ws+gap"}"#, ScreenKind::WsGap),
            (r#"{"screen": "none"}"#, ScreenKind::None),
        ] {
            let cfg = Config::from_json(text).unwrap();
            assert_eq!(cfg.screen, kind);
            assert_eq!(cfg.path_config(1.0).screen, kind);
        }
        // Roundtrip through to_json.
        let mut cfg = Config::default();
        cfg.screen = ScreenKind::TlfreGap;
        let back = Config::from_json(&cfg.to_json().to_string_pretty()).unwrap();
        assert_eq!(back.screen, ScreenKind::TlfreGap);
    }

    #[test]
    fn single_point_grid_and_cv_folds_parse() {
        // n_lambda == 1 is the legal degenerate grid (the λmax endpoint).
        let cfg = Config::from_json(r#"{"n_lambda": 1, "k_folds": 3}"#).unwrap();
        assert_eq!(cfg.n_lambda, 1);
        assert_eq!(cfg.k_folds, 3);
        cfg.path_config(1.0).validate();
    }

    #[test]
    fn perf_knobs_parse_and_thread_into_path_config() {
        let cfg = Config::from_json(
            r#"{"lipschitz_refresh_every": 4, "parallel_bcd_groups": true, "solver": "bcd"}"#,
        )
        .unwrap();
        assert_eq!(cfg.lipschitz_refresh_every, Some(4));
        assert!(cfg.parallel_bcd_groups);
        let pc = cfg.path_config(1.0);
        assert_eq!(pc.lipschitz_refresh_every, Some(4));
        assert!(pc.parallel_bcd_groups);
        // Explicit null disables the refresh.
        let off = Config::from_json(r#"{"lipschitz_refresh_every": null}"#).unwrap();
        assert_eq!(off.lipschitz_refresh_every, None);
    }

    #[test]
    fn partial_overrides() {
        let cfg = Config::from_json(r#"{"n_lambda": 25, "alphas": [1.0]}"#).unwrap();
        assert_eq!(cfg.n_lambda, 25);
        assert_eq!(cfg.alphas, vec![1.0]);
        assert_eq!(cfg.tol, Config::default().tol);
    }

    #[test]
    fn budget_and_safety_controls_parse_and_thread_into_path_config() {
        // The controls that used to be PathConfig-only are now reachable
        // from every JSON surface through the one shared parse path.
        let cfg = Config::from_json(
            r#"{"max_seconds": 2.5, "verify_safety": true, "gap_inflation": 0.5}"#,
        )
        .unwrap();
        assert_eq!(cfg.max_seconds, Some(2.5));
        assert!(cfg.verify_safety);
        assert_eq!(cfg.gap_inflation, 0.5);
        let pc = cfg.path_config(1.0);
        assert_eq!(pc.max_seconds, Some(2.5));
        assert!(pc.verify_safety);
        // Explicit null disables the budget; bad values are typed errors.
        let off = Config::from_json(r#"{"max_seconds": null}"#).unwrap();
        assert_eq!(off.max_seconds, None);
        assert!(Config::from_json(r#"{"max_seconds": 0.0}"#).is_err());
        assert!(Config::from_json(r#"{"max_seconds": -1.0}"#).is_err());
        assert!(Config::from_json(r#"{"verify_safety": "yes"}"#).is_err());
        assert!(Config::from_json(r#"{"gap_inflation": -0.5}"#).is_err());
        // Roundtrip: the new keys are emitted too.
        let back = Config::from_json(&cfg.to_json().to_string_pretty()).unwrap();
        assert_eq!(cfg, back);
    }

    #[test]
    fn defaults_are_single_sourced_through_solve_controls() {
        // Config's control defaults ARE SolveControls::default() — there
        // is no second copy of the literals to drift.
        let cfg = Config::default();
        assert_eq!(cfg.controls, SolveControls::default());
        let pc = cfg.path_config(1.0);
        assert_eq!(pc.controls, SolveControls::default());
    }
}
