//! Experiment configuration.
//!
//! JSON-backed (via [`crate::util::json`]; serde is unavailable offline) so
//! experiment definitions can be versioned and passed to the CLI with
//! `--config`. All fields have defaults — an empty object is a valid
//! config — and unknown keys are rejected to catch typos.

use crate::coordinator::runner::SolverKind;
use crate::screening::rule::ScreenKind;
use crate::util::json::Json;
use crate::bail;
use crate::error::{Context, Result};

/// Top-level experiment configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct Config {
    /// α values (problem (3)); default = paper's seven tan(ψ) values.
    pub alphas: Vec<f64>,
    /// Number of λ grid points.
    pub n_lambda: usize,
    /// λ_min/λ_max.
    pub lambda_min_ratio: f64,
    /// Solver: "fista" | "bcd".
    pub solver: SolverKind,
    /// Relative duality-gap tolerance.
    pub tol: f64,
    /// Iteration cap per solve.
    pub max_iter: usize,
    /// Dataset seed.
    pub seed: u64,
    /// Feature-dimension scale for simulated real data sets.
    pub scale: f64,
    /// Fold count for the `cv` command / [`crate::coordinator::cv`].
    pub k_folds: usize,
    /// Amortized per-view Lipschitz refresh cadence (path steps); `None`
    /// (default) reuses the full-matrix constants for the whole path. See
    /// [`crate::coordinator::runner::PathConfig::lipschitz_refresh_every`].
    pub lipschitz_refresh_every: Option<usize>,
    /// Pool-parallel red-black BCD group sweeps (no effect under FISTA).
    /// See [`crate::coordinator::runner::PathConfig::parallel_bcd_groups`].
    pub parallel_bcd_groups: bool,
    /// Screening pipeline: "tlfre" (default) | "tlfre+gap" | "gap" |
    /// "strong+kkt" | "none". See
    /// [`crate::coordinator::runner::PathConfig::screen`].
    pub screen: ScreenKind,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            alphas: crate::coordinator::path::alpha_grid_from_angles(
                &crate::coordinator::path::PAPER_ALPHA_ANGLES,
            ),
            n_lambda: 100,
            lambda_min_ratio: 0.01,
            solver: SolverKind::Fista,
            tol: 1e-6,
            max_iter: 20_000,
            seed: 42,
            scale: 0.1,
            k_folds: 5,
            lipschitz_refresh_every: None,
            parallel_bcd_groups: false,
            screen: ScreenKind::Tlfre,
        }
    }
}

impl Config {
    /// Parse from JSON text; unknown keys are errors.
    pub fn from_json(text: &str) -> Result<Config> {
        let v = Json::parse(text).context("config is not valid JSON")?;
        let obj = v.as_obj().context("config must be a JSON object")?;
        let mut cfg = Config::default();
        for (k, val) in obj {
            match k.as_str() {
                "alphas" => {
                    let arr = val.as_arr().context("alphas must be an array")?;
                    cfg.alphas = arr
                        .iter()
                        .map(|x| x.as_f64().context("alpha must be a number"))
                        .collect::<Result<_>>()?;
                    if cfg.alphas.is_empty() {
                        bail!("alphas must be non-empty");
                    }
                    if cfg.alphas.iter().any(|&a| a <= 0.0) {
                        bail!("alphas must be positive");
                    }
                }
                "n_lambda" => cfg.n_lambda = val.as_usize().context("n_lambda must be a nonnegative integer")?,
                "lambda_min_ratio" => {
                    cfg.lambda_min_ratio = val.as_f64().context("lambda_min_ratio must be a number")?;
                    if !(cfg.lambda_min_ratio > 0.0 && cfg.lambda_min_ratio < 1.0) {
                        bail!("lambda_min_ratio must be in (0, 1)");
                    }
                }
                "solver" => {
                    cfg.solver = match val.as_str() {
                        Some("fista") => SolverKind::Fista,
                        Some("bcd") => SolverKind::Bcd,
                        other => bail!("unknown solver {other:?} (want \"fista\" or \"bcd\")"),
                    }
                }
                "tol" => cfg.tol = val.as_f64().context("tol must be a number")?,
                "max_iter" => cfg.max_iter = val.as_usize().context("max_iter must be an integer")?,
                "lipschitz_refresh_every" => {
                    // null = cached mode (the default); K ≥ 1 = refresh cadence.
                    cfg.lipschitz_refresh_every = match val {
                        Json::Null => None,
                        other => {
                            let k = other
                                .as_usize()
                                .context("lipschitz_refresh_every must be a positive integer or null")?;
                            if k == 0 {
                                bail!("lipschitz_refresh_every must be ≥ 1 (or null to disable)");
                            }
                            Some(k)
                        }
                    };
                }
                "parallel_bcd_groups" => {
                    cfg.parallel_bcd_groups =
                        val.as_bool().context("parallel_bcd_groups must be a boolean")?;
                }
                "screen" => {
                    let s = val.as_str().context("screen must be a string")?;
                    cfg.screen = ScreenKind::parse(s).with_context(|| {
                        format!(
                            "unknown screen pipeline '{s}' \
                             (tlfre|tlfre+gap|gap|strong+kkt|none)"
                        )
                    })?;
                }
                "seed" => cfg.seed = val.as_usize().context("seed must be an integer")? as u64,
                "scale" => {
                    cfg.scale = val.as_f64().context("scale must be a number")?;
                    if !(cfg.scale > 0.0 && cfg.scale <= 1.0) {
                        bail!("scale must be in (0, 1]");
                    }
                }
                "k_folds" => {
                    cfg.k_folds = val.as_usize().context("k_folds must be an integer")?;
                    if cfg.k_folds < 2 {
                        bail!("k_folds must be ≥ 2");
                    }
                }
                other => bail!("unknown config key '{other}'"),
            }
        }
        // n_lambda == 1 is the legal single-point grid (λmax alone); only
        // an empty grid is rejected (matches PathConfig::validate).
        if cfg.n_lambda < 1 {
            bail!("n_lambda must be ≥ 1");
        }
        Ok(cfg)
    }

    /// Load from a file path.
    pub fn from_file(path: &std::path::Path) -> Result<Config> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading config {path:?}"))?;
        Self::from_json(&text)
    }

    /// Serialize back to JSON (for run manifests).
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("alphas", self.alphas.clone())
            .set("n_lambda", self.n_lambda)
            .set("lambda_min_ratio", self.lambda_min_ratio)
            .set(
                "solver",
                match self.solver {
                    SolverKind::Fista => "fista",
                    SolverKind::Bcd => "bcd",
                },
            )
            .set("tol", self.tol)
            .set("max_iter", self.max_iter)
            .set("seed", self.seed as usize)
            .set("scale", self.scale)
            .set("k_folds", self.k_folds)
            .set(
                "lipschitz_refresh_every",
                match self.lipschitz_refresh_every {
                    Some(k) => Json::from(k),
                    None => Json::Null,
                },
            )
            .set("parallel_bcd_groups", self.parallel_bcd_groups)
            .set("screen", self.screen.as_str())
    }

    /// Per-α path configuration.
    pub fn path_config(&self, alpha: f64) -> crate::coordinator::runner::PathConfig {
        crate::coordinator::runner::PathConfig {
            alpha,
            n_lambda: self.n_lambda,
            lambda_min_ratio: self.lambda_min_ratio,
            solver: self.solver,
            tol: self.tol,
            max_iter: self.max_iter,
            verify_safety: false,
            materialize_reduced: false,
            gap_inflation: 0.0,
            exact_view_lipschitz: false,
            lipschitz_refresh_every: self.lipschitz_refresh_every,
            parallel_bcd_groups: self.parallel_bcd_groups,
            screen: self.screen,
            max_seconds: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_object_is_default() {
        let cfg = Config::from_json("{}").unwrap();
        assert_eq!(cfg, Config::default());
        assert_eq!(cfg.alphas.len(), 7);
    }

    #[test]
    fn roundtrip_through_json() {
        let mut cfg = Config::default();
        cfg.n_lambda = 50;
        cfg.solver = SolverKind::Bcd;
        cfg.tol = 1e-8;
        cfg.lipschitz_refresh_every = Some(5);
        cfg.parallel_bcd_groups = true;
        let text = cfg.to_json().to_string_pretty();
        let back = Config::from_json(&text).unwrap();
        assert_eq!(cfg, back);
    }

    #[test]
    fn rejects_unknown_keys_and_bad_values() {
        assert!(Config::from_json(r#"{"n_lamda": 10}"#).is_err()); // typo
        assert!(Config::from_json(r#"{"solver": "adam"}"#).is_err());
        assert!(Config::from_json(r#"{"lambda_min_ratio": 2.0}"#).is_err());
        assert!(Config::from_json(r#"{"alphas": [1.0, -2.0]}"#).is_err());
        assert!(Config::from_json(r#"{"alphas": []}"#).is_err());
        assert!(Config::from_json(r#"{"n_lambda": 0}"#).is_err());
        assert!(Config::from_json(r#"{"scale": 0.0}"#).is_err());
        assert!(Config::from_json(r#"{"k_folds": 1}"#).is_err());
        assert!(Config::from_json(r#"{"lipschitz_refresh_every": 0}"#).is_err());
        assert!(Config::from_json(r#"{"lipschitz_refresh_every": "often"}"#).is_err());
        assert!(Config::from_json(r#"{"parallel_bcd_groups": 1}"#).is_err());
        assert!(Config::from_json(r#"{"screen": "magic"}"#).is_err());
        assert!(Config::from_json(r#"{"screen": 3}"#).is_err());
        assert!(Config::from_json("not json").is_err());
    }

    #[test]
    fn screen_key_parses_and_threads_into_path_config() {
        for (text, kind) in [
            (r#"{"screen": "tlfre"}"#, ScreenKind::Tlfre),
            (r#"{"screen": "tlfre+gap"}"#, ScreenKind::TlfreGap),
            (r#"{"screen": "gap"}"#, ScreenKind::Gap),
            (r#"{"screen": "strong+kkt"}"#, ScreenKind::StrongKkt),
            (r#"{"screen": "none"}"#, ScreenKind::None),
        ] {
            let cfg = Config::from_json(text).unwrap();
            assert_eq!(cfg.screen, kind);
            assert_eq!(cfg.path_config(1.0).screen, kind);
        }
        // Roundtrip through to_json.
        let mut cfg = Config::default();
        cfg.screen = ScreenKind::TlfreGap;
        let back = Config::from_json(&cfg.to_json().to_string_pretty()).unwrap();
        assert_eq!(back.screen, ScreenKind::TlfreGap);
    }

    #[test]
    fn single_point_grid_and_cv_folds_parse() {
        // n_lambda == 1 is the legal degenerate grid (the λmax endpoint).
        let cfg = Config::from_json(r#"{"n_lambda": 1, "k_folds": 3}"#).unwrap();
        assert_eq!(cfg.n_lambda, 1);
        assert_eq!(cfg.k_folds, 3);
        cfg.path_config(1.0).validate();
    }

    #[test]
    fn perf_knobs_parse_and_thread_into_path_config() {
        let cfg = Config::from_json(
            r#"{"lipschitz_refresh_every": 4, "parallel_bcd_groups": true, "solver": "bcd"}"#,
        )
        .unwrap();
        assert_eq!(cfg.lipschitz_refresh_every, Some(4));
        assert!(cfg.parallel_bcd_groups);
        let pc = cfg.path_config(1.0);
        assert_eq!(pc.lipschitz_refresh_every, Some(4));
        assert!(pc.parallel_bcd_groups);
        // Explicit null disables the refresh.
        let off = Config::from_json(r#"{"lipschitz_refresh_every": null}"#).unwrap();
        assert_eq!(off.lipschitz_refresh_every, None);
    }

    #[test]
    fn partial_overrides() {
        let cfg = Config::from_json(r#"{"n_lambda": 25, "alphas": [1.0]}"#).unwrap();
        assert_eq!(cfg.n_lambda, 25);
        assert_eq!(cfg.alphas, vec![1.0]);
        assert_eq!(cfg.tol, Config::default().tol);
    }
}
