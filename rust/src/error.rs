//! Minimal `anyhow`-compatible error handling.
//!
//! The offline crate set has no `anyhow`; this module provides the small
//! slice of its API the crate uses: an opaque [`Error`] carrying a context
//! chain, the [`Result`] alias with a defaulted error type, a [`Context`]
//! extension trait for `Result`/`Option`, and the [`anyhow!`]/[`bail!`]/
//! [`ensure!`] macros. `{e}` prints the outermost message, `{e:#}` the full
//! chain joined with `: ` — matching anyhow's formatting contract, which
//! the CLI and tests rely on.

use std::fmt;

/// An opaque error: a chain of context messages, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build from a single message.
    pub fn msg(msg: impl Into<String>) -> Error {
        Error { chain: vec![msg.into()] }
    }

    /// Prepend a context message (the new outermost layer).
    pub fn context(mut self, msg: impl Into<String>) -> Error {
        self.chain.insert(0, msg.into());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> &[String] {
        &self.chain
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(String::as_str).unwrap_or("unknown error"))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Debug prints the whole chain (what `.expect()`/`.unwrap()` show).
        f.write_str(&self.chain.join(": "))
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`; that is
// what makes the blanket `From` below coherent (same trick as anyhow).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // Fold the source chain into the message chain.
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `Result` with the crate error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(...)`.
pub trait Context<T> {
    /// Wrap the error (or `None`) with a context message.
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    /// Lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

// `E: Into<Error>` covers both foreign errors (via the blanket `From` above,
// which folds their `source()` chain) and our own `Error` (via the reflexive
// `From<T> for T`, preserving its existing chain) — so nested `.context(...)`
// calls accumulate the full chain instead of flattening to one message.
impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into().context(ctx.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f().to_string()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::error::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Bail unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "no such file")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e: Error = Err::<(), _>(io_err()).context("reading config").unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: no such file");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(format!("{e}").contains("no such file"));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing key").unwrap_err();
        assert_eq!(format!("{e}"), "missing key");
        assert_eq!(Some(1u32).context("x").unwrap(), 1);
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("bad value {}", 7);
        assert_eq!(format!("{e}"), "bad value 7");
        fn f(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            if x > 100 {
                bail!("x too large");
            }
            Ok(x)
        }
        assert!(f(5).is_ok());
        assert!(f(-1).is_err());
        assert!(f(200).is_err());
    }

    #[test]
    fn nested_context_preserves_full_chain() {
        fn inner() -> Result<()> {
            Err(io_err()).context("parsing HLO text")
        }
        let e = inner().context("loading artifact").unwrap_err();
        assert_eq!(format!("{e}"), "loading artifact");
        assert_eq!(format!("{e:#}"), "loading artifact: parsing HLO text: no such file");
    }

    #[test]
    fn with_context_lazy() {
        let e: Error = Err::<(), _>(io_err()).with_context(|| format!("step {}", 3)).unwrap_err();
        assert_eq!(format!("{e}"), "step 3");
    }
}
