//! Data substrate: generators, the simulated-data registry and binary IO.
//!
//! The paper evaluates on two synthetic designs (reproduced exactly in
//! [`synthetic`]) and seven real data sets. None of the real sets are
//! available in this offline environment (ADNI is restricted-access; the
//! rest are not downloadable), so [`registry`] builds *simulated
//! equivalents* with matching dimensions and matched screening-relevant
//! geometry (column-norm spread, correlation structure, group layout,
//! response construction). DESIGN.md §5 documents each substitution.
//!
//! [`validate`] screens inputs (non-finite entries, zero-norm columns,
//! degenerate groups) with typed errors before any solve touches them.

pub mod io;
pub mod registry;
pub mod synthetic;
pub mod validate;

use crate::groups::GroupStructure;
use crate::linalg::DenseMatrix;

/// A fully materialized regression data set.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// Human-readable name (used in reports).
    pub name: String,
    /// Design matrix `N × p`.
    pub x: DenseMatrix,
    /// Response vector, length `N`.
    pub y: Vec<f32>,
    /// Group partition of the features.
    pub groups: GroupStructure,
    /// Ground-truth coefficients when the set is synthetic.
    pub beta_star: Option<Vec<f32>>,
}

impl Dataset {
    #[inline]
    pub fn n(&self) -> usize {
        self.x.rows()
    }

    #[inline]
    pub fn p(&self) -> usize {
        self.x.cols()
    }

    /// Short description line for logs and reports.
    pub fn describe(&self) -> String {
        format!(
            "{}: {}×{} ({} groups)",
            self.name,
            self.n(),
            self.p(),
            self.groups.n_groups()
        )
    }
}
