//! Synthetic designs — exactly the paper's Section 6.1.1 recipe.
//!
//! True model: `y = Xβ* + 0.01ε`, `ε ~ N(0, I)`.
//!
//! * **Synthetic 1** — `X` entries i.i.d. N(0,1), 250 × 10000 in 1000
//!   groups; γ₁ = γ₂ = 10%.
//! * **Synthetic 2** — columns follow an AR(1) process with
//!   `corr(x_i, x_j) = 0.5^{|i−j|}`; γ₁ = γ₂ = 20%.
//!
//! β* construction: pick γ₁ percent of the groups at random, then γ₂
//! percent of the features in each picked group; populate the picked
//! entries from N(0,1), the rest are 0.

use super::io::DatasetWriter;
use super::Dataset;
use crate::error::Result;
use crate::groups::GroupStructure;
use crate::linalg::{ops, CscMatrix, DenseMatrix, DesignMatrix};
use crate::util::Rng;

/// Column correlation structure.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Correlation {
    /// i.i.d. N(0, 1) entries (Synthetic 1).
    Iid,
    /// AR(1) across the feature index: `corr(x_i, x_j) = ρ^{|i−j|}`
    /// (Synthetic 2 uses ρ = 0.5).
    Ar(f64),
}

/// Generator specification.
#[derive(Debug, Clone)]
pub struct SyntheticSpec {
    pub name: String,
    pub n: usize,
    pub p: usize,
    pub n_groups: usize,
    pub correlation: Correlation,
    /// Percent of groups carrying signal (the paper's γ₁), in [0, 100].
    pub gamma1: f64,
    /// Percent of features carrying signal inside a signal group (γ₂).
    pub gamma2: f64,
    /// Noise standard deviation (paper: 0.01).
    pub noise: f64,
}

impl SyntheticSpec {
    /// Paper's Synthetic 1 at full scale (250 × 10000, 1000 groups).
    pub fn synthetic1() -> SyntheticSpec {
        SyntheticSpec {
            name: "Synthetic 1".into(),
            n: 250,
            p: 10_000,
            n_groups: 1000,
            correlation: Correlation::Iid,
            gamma1: 10.0,
            gamma2: 10.0,
            noise: 0.01,
        }
    }

    /// Paper's Synthetic 2 at full scale.
    pub fn synthetic2() -> SyntheticSpec {
        SyntheticSpec {
            name: "Synthetic 2".into(),
            n: 250,
            p: 10_000,
            n_groups: 1000,
            correlation: Correlation::Ar(0.5),
            gamma1: 20.0,
            gamma2: 20.0,
            noise: 0.01,
        }
    }

    /// Synthetic 1 recipe at custom dimensions (tests / reduced benches).
    pub fn synthetic1_scaled(n: usize, p: usize, n_groups: usize) -> SyntheticSpec {
        SyntheticSpec { n, p, n_groups, name: format!("Synthetic 1 ({n}x{p})"), ..Self::synthetic1() }
    }

    /// Synthetic 2 recipe at custom dimensions.
    pub fn synthetic2_scaled(n: usize, p: usize, n_groups: usize) -> SyntheticSpec {
        SyntheticSpec { n, p, n_groups, name: format!("Synthetic 2 ({n}x{p})"), ..Self::synthetic2() }
    }
}

/// Fill the design matrix per the correlation spec.
fn fill_design(spec: &SyntheticSpec, rng: &mut Rng) -> DenseMatrix {
    let (n, p) = (spec.n, spec.p);
    let mut x = DenseMatrix::zeros(n, p);
    match spec.correlation {
        Correlation::Iid => {
            rng.fill_gaussian_f32(x.data_mut());
        }
        Correlation::Ar(rho) => {
            // Per sample (row), an AR(1) walk across the feature index:
            // x_{i,0} ~ N(0,1); x_{i,j} = ρ x_{i,j−1} + √(1−ρ²) ε.
            // This yields corr(x_i, x_j) = ρ^{|i−j|} exactly.
            let w = (1.0 - rho * rho).sqrt();
            let mut prev = vec![0.0f64; n];
            for v in prev.iter_mut() {
                *v = rng.gaussian();
            }
            for i in 0..n {
                x.set(i, 0, prev[i] as f32);
            }
            for j in 1..p {
                for i in 0..n {
                    let v = rho * prev[i] + w * rng.gaussian();
                    prev[i] = v;
                    x.set(i, j, v as f32);
                }
            }
        }
    }
    x
}

/// Build β* per the paper's γ₁/γ₂ recipe (γ values in percent).
fn build_beta_gammas(
    gamma1: f64,
    gamma2: f64,
    groups: &GroupStructure,
    rng: &mut Rng,
) -> Vec<f32> {
    let g_cnt = groups.n_groups();
    let k_groups = ((gamma1 / 100.0 * g_cnt as f64).round() as usize).clamp(1, g_cnt);
    let chosen = rng.sample_indices(g_cnt, k_groups);
    let mut beta = vec![0.0f32; groups.n_features()];
    for &g in &chosen {
        let (s, e) = groups.range(g);
        let m = e - s;
        let k_feat = ((gamma2 / 100.0 * m as f64).round() as usize).clamp(1, m);
        for &off in &rng.sample_indices(m, k_feat) {
            beta[s + off] = rng.gaussian() as f32;
        }
    }
    beta
}

fn build_beta(spec: &SyntheticSpec, groups: &GroupStructure, rng: &mut Rng) -> Vec<f32> {
    build_beta_gammas(spec.gamma1, spec.gamma2, groups, rng)
}

/// Generate a data set from the spec (deterministic in `seed`).
pub fn generate_synthetic(spec: &SyntheticSpec, seed: u64) -> Dataset {
    assert!(spec.p % spec.n_groups == 0, "p must split into equal groups (paper setup)");
    let mut rng = Rng::seed_from_u64(seed);
    let x = fill_design(spec, &mut rng);
    let groups = GroupStructure::uniform(spec.p, spec.n_groups);
    let beta = build_beta(spec, &groups, &mut rng);
    let mut y = vec![0.0f32; spec.n];
    x.matvec(&beta, &mut y);
    for v in y.iter_mut() {
        *v += (spec.noise * rng.gaussian()) as f32;
    }
    Dataset { name: spec.name.clone(), x, y, groups, beta_star: Some(beta) }
}

// ---------------------------------------------------------------------------
// Streaming generation (out-of-core)

/// Column-block replay of [`fill_design`]'s draw sequence.
///
/// Produces the design in col-major blocks while consuming the RNG in
/// **exactly** the order the in-RAM generator does (Iid: one gaussian per
/// element in col-major order; AR(1): `n` initial draws, then `n` per
/// subsequent column with the walk state carried in `prev`), so a streamed
/// dataset is bit-identical to its in-RAM counterpart. Box–Muller caches a
/// spare draw inside [`Rng`], which makes the draw *order* load-bearing —
/// any reordering would shift every later value.
struct DesignStream {
    n: usize,
    p: usize,
    state: DesignState,
    next_col: usize,
}

enum DesignState {
    Iid,
    Ar { rho: f64, w: f64, prev: Vec<f64> },
}

impl DesignStream {
    fn new(spec: &SyntheticSpec) -> DesignStream {
        let state = match spec.correlation {
            Correlation::Iid => DesignState::Iid,
            Correlation::Ar(rho) => {
                DesignState::Ar { rho, w: (1.0 - rho * rho).sqrt(), prev: Vec::new() }
            }
        };
        DesignStream { n: spec.n, p: spec.p, state, next_col: 0 }
    }

    /// Generate the next ≤ `max_cols` columns into `out` (col-major,
    /// resized to exactly `n·k`); returns `k` (0 when exhausted).
    fn next_block(&mut self, rng: &mut Rng, out: &mut Vec<f32>, max_cols: usize) -> usize {
        let n = self.n;
        let k = max_cols.min(self.p - self.next_col);
        out.clear();
        out.resize(n * k, 0.0);
        match &mut self.state {
            DesignState::Iid => rng.fill_gaussian_f32(out),
            DesignState::Ar { rho, w, prev } => {
                for c in 0..k {
                    let col = &mut out[c * n..(c + 1) * n];
                    if self.next_col + c == 0 {
                        prev.resize(n, 0.0);
                        for (v, o) in prev.iter_mut().zip(col.iter_mut()) {
                            *v = rng.gaussian();
                            *o = *v as f32;
                        }
                    } else {
                        for (v, o) in prev.iter_mut().zip(col.iter_mut()) {
                            *v = *rho * *v + *w * rng.gaussian();
                            *o = *v as f32;
                        }
                    }
                }
            }
        }
        self.next_col += k;
        k
    }
}

/// Stream a synthetic dataset straight to a `TLFREDS1` file in bounded
/// memory — the out-of-core twin of [`generate_synthetic`] + `io::save`.
///
/// Peak resident state is one `n·block_cols` column block plus the `n`-dim
/// response, `p`-dim β* and (for AR) the `n`-dim walk state — independent of
/// the `n·p` payload size, so arbitrarily large files are producible.
///
/// The output is **byte-identical** to
/// `io::save(&generate_synthetic(spec, seed), path)`:
///
/// * pass 1 replays [`fill_design`]'s exact RNG draw order per column block
///   (see [`DesignStream`]) and appends each block via
///   [`DatasetWriter::write_cols`];
/// * β* is then drawn from the post-design RNG state, as in-RAM;
/// * pass 2 *regenerates* the design from a clone of the starting RNG
///   (cheaper than re-reading the file, and no flush dance) and folds
///   `y += β*_j · x_j` per nonzero column in ascending order — the very
///   accumulation sequence `DesignMatrix::matvec` is contractually bitwise
///   equal to — before the noise draws complete the stream.
pub fn generate_synthetic_streaming(
    spec: &SyntheticSpec,
    seed: u64,
    path: &std::path::Path,
    block_cols: usize,
) -> Result<()> {
    assert!(spec.p % spec.n_groups == 0, "p must split into equal groups (paper setup)");
    let block = block_cols.max(1);
    let groups = GroupStructure::uniform(spec.p, spec.n_groups);
    let sizes: Vec<usize> = (0..groups.n_groups()).map(|g| groups.size(g)).collect();
    let mut rng = Rng::seed_from_u64(seed);
    let design_rng = rng.clone();

    // Pass 1: stream X to disk block by block.
    let mut w = DatasetWriter::create(path, &spec.name, spec.n, spec.p, &sizes, true)?;
    let mut stream = DesignStream::new(spec);
    let mut buf: Vec<f32> = Vec::new();
    loop {
        let k = stream.next_block(&mut rng, &mut buf, block);
        if k == 0 {
            break;
        }
        w.write_cols(&buf)?;
    }

    // β* continues from the post-design RNG state (same order as in-RAM).
    let beta = build_beta_gammas(spec.gamma1, spec.gamma2, &groups, &mut rng);

    // Pass 2: regenerate the design and accumulate y = Xβ* column-ascending.
    let mut replay = design_rng;
    let mut stream2 = DesignStream::new(spec);
    let mut y = vec![0.0f32; spec.n];
    let mut j0 = 0;
    loop {
        let k = stream2.next_block(&mut replay, &mut buf, block);
        if k == 0 {
            break;
        }
        for c in 0..k {
            let bj = beta[j0 + c];
            if bj != 0.0 {
                ops::axpy(bj, &buf[c * spec.n..(c + 1) * spec.n], &mut y);
            }
        }
        j0 += k;
    }
    for v in y.iter_mut() {
        *v += (spec.noise * rng.gaussian()) as f32;
    }
    w.finish(&y, Some(&beta))
}

// ---------------------------------------------------------------------------
// Sparse synthetic designs (CSC-native)

/// Specification for a sparse synthetic design: the Synthetic-1 recipe with
/// the dense gaussian design replaced by a Bernoulli(density)·N(0,1) sparse
/// design, built directly in CSC form. This is the one-hot-genomics /
/// text-n-gram regime where safe screening plus sparse storage compound.
#[derive(Debug, Clone)]
pub struct SparseSyntheticSpec {
    pub name: String,
    pub n: usize,
    pub p: usize,
    pub n_groups: usize,
    /// Expected fraction of nonzero entries, in (0, 1].
    pub density: f64,
    /// Percent of groups carrying signal (γ₁).
    pub gamma1: f64,
    /// Percent of features carrying signal inside a signal group (γ₂).
    pub gamma2: f64,
    /// Noise standard deviation.
    pub noise: f64,
}

impl SparseSyntheticSpec {
    /// Synthetic-1-style recipe at the given dimensions and density.
    pub fn new(n: usize, p: usize, n_groups: usize, density: f64) -> SparseSyntheticSpec {
        assert!(density > 0.0 && density <= 1.0, "density must be in (0, 1]");
        SparseSyntheticSpec {
            name: format!("Sparse synthetic ({n}x{p}, {:.1}% dense)", density * 100.0),
            n,
            p,
            n_groups,
            density,
            gamma1: 10.0,
            gamma2: 10.0,
            noise: 0.01,
        }
    }
}

/// A sparse data set: identical to [`Dataset`] but with CSC design storage.
#[derive(Debug, Clone)]
pub struct SparseDataset {
    pub name: String,
    pub x: CscMatrix,
    pub y: Vec<f32>,
    pub groups: GroupStructure,
    pub beta_star: Vec<f32>,
}

impl SparseDataset {
    /// Short description line for logs and reports.
    pub fn describe(&self) -> String {
        format!(
            "{}: {}×{} ({} groups, nnz {} = {:.2}%)",
            self.name,
            self.x.rows(),
            self.x.cols(),
            self.groups.n_groups(),
            self.x.nnz(),
            self.x.density() * 100.0
        )
    }
}

/// Generate a sparse data set from the spec (deterministic in `seed`).
///
/// Entries are iid `Bernoulli(density) · N(0, 1)`, scaled by `1/√density`
/// so columns have unit-variance rows and `E‖x_j‖² = n` matches the dense
/// Synthetic-1 geometry (keeps λmax and the screening radii comparable
/// across densities).
pub fn generate_sparse_synthetic(spec: &SparseSyntheticSpec, seed: u64) -> SparseDataset {
    assert!(spec.p % spec.n_groups == 0, "p must split into equal groups (paper setup)");
    let mut rng = Rng::seed_from_u64(seed);
    let scale = (1.0 / spec.density).sqrt() as f32;
    let mut indptr = Vec::with_capacity(spec.p + 1);
    let mut indices: Vec<u32> = Vec::new();
    let mut values: Vec<f32> = Vec::new();
    indptr.push(0usize);
    for _ in 0..spec.p {
        for i in 0..spec.n {
            if rng.uniform_range(0.0, 1.0) < spec.density {
                indices.push(i as u32);
                values.push(rng.gaussian() as f32 * scale);
            }
        }
        indptr.push(indices.len());
    }
    let x = CscMatrix::from_parts(spec.n, spec.p, indptr, indices, values);
    let groups = GroupStructure::uniform(spec.p, spec.n_groups);
    let beta = build_beta_gammas(spec.gamma1, spec.gamma2, &groups, &mut rng);
    let mut y = vec![0.0f32; spec.n];
    x.matvec(&beta, &mut y);
    for v in y.iter_mut() {
        *v += (spec.noise * rng.gaussian()) as f32;
    }
    SparseDataset { name: spec.name.clone(), x, y, groups, beta_star: beta }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::ops;

    #[test]
    fn dims_and_determinism() {
        let spec = SyntheticSpec::synthetic1_scaled(30, 200, 20);
        let a = generate_synthetic(&spec, 7);
        let b = generate_synthetic(&spec, 7);
        assert_eq!(a.x.data(), b.x.data());
        assert_eq!(a.y, b.y);
        assert_eq!(a.n(), 30);
        assert_eq!(a.p(), 200);
        assert_eq!(a.groups.n_groups(), 20);
        let c = generate_synthetic(&spec, 8);
        assert_ne!(a.y, c.y);
    }

    #[test]
    fn beta_sparsity_matches_gammas() {
        let spec = SyntheticSpec::synthetic1_scaled(10, 1000, 100);
        let ds = generate_synthetic(&spec, 1);
        let beta = ds.beta_star.unwrap();
        // 10% of 100 groups = 10 groups; 10% of 10 features each = 1 →
        // exactly 10 nonzeros.
        let nnz = beta.iter().filter(|&&b| b != 0.0).count();
        assert_eq!(nnz, 10);
        // They sit in exactly 10 distinct groups.
        let mut gset = std::collections::BTreeSet::new();
        for (j, &b) in beta.iter().enumerate() {
            if b != 0.0 {
                gset.insert(ds.groups.group_of(j));
            }
        }
        assert_eq!(gset.len(), 10);
    }

    #[test]
    fn iid_moments() {
        let spec = SyntheticSpec::synthetic1_scaled(50, 400, 40);
        let ds = generate_synthetic(&spec, 2);
        let data = ds.x.data();
        let mean: f64 = data.iter().map(|&v| v as f64).sum::<f64>() / data.len() as f64;
        let var: f64 =
            data.iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>() / data.len() as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn ar_correlation_structure() {
        let spec = SyntheticSpec::synthetic2_scaled(2000, 50, 10);
        let ds = generate_synthetic(&spec, 3);
        let corr = |a: &[f32], b: &[f32]| -> f64 {
            let d = ops::dot(a, b);
            d / (ops::nrm2(a) * ops::nrm2(b))
        };
        // lag-1 ≈ 0.5, lag-2 ≈ 0.25, lag-4 ≈ 0.0625
        let c1 = corr(ds.x.col(10), ds.x.col(11));
        let c2 = corr(ds.x.col(10), ds.x.col(12));
        let c4 = corr(ds.x.col(10), ds.x.col(14));
        assert!((c1 - 0.5).abs() < 0.07, "lag1={c1}");
        assert!((c2 - 0.25).abs() < 0.07, "lag2={c2}");
        assert!(c4.abs() < 0.15, "lag4={c4}");
    }

    #[test]
    fn sparse_generator_density_and_determinism() {
        let spec = SparseSyntheticSpec::new(40, 400, 40, 0.05);
        let a = generate_sparse_synthetic(&spec, 5);
        let b = generate_sparse_synthetic(&spec, 5);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
        // Realized density within 30% of nominal (binomial concentration).
        let d = a.x.density();
        assert!((d - 0.05).abs() < 0.015, "density {d}");
        // Column second moments ≈ n thanks to the 1/√density scaling.
        let norms = a.x.col_norms();
        let mean_sq: f64 = norms.iter().map(|&v| v * v).sum::<f64>() / norms.len() as f64;
        assert!((mean_sq - 40.0).abs() < 8.0, "mean ‖x_j‖² = {mean_sq}");
        // Signal present.
        assert!(a.beta_star.iter().any(|&v| v != 0.0));
        assert!(ops::nrm2(&a.y) > 0.0);
    }

    #[test]
    fn streamed_file_is_byte_identical_to_in_ram_save() {
        for (spec, seed) in [
            (SyntheticSpec::synthetic1_scaled(12, 60, 6), 21u64),
            (SyntheticSpec::synthetic2_scaled(9, 40, 4), 22),
        ] {
            let dir = std::env::temp_dir().join("tlfre_stream_test");
            std::fs::create_dir_all(&dir).unwrap();
            let a = dir.join(format!("ram_{seed}.bin"));
            let b = dir.join(format!("stream_{seed}.bin"));
            crate::data::io::save(&generate_synthetic(&spec, seed), &a).unwrap();
            for block in [1usize, 7, 64, 10_000] {
                generate_synthetic_streaming(&spec, seed, &b, block).unwrap();
                assert_eq!(
                    std::fs::read(&a).unwrap(),
                    std::fs::read(&b).unwrap(),
                    "block={block} spec={}",
                    spec.name
                );
            }
            std::fs::remove_file(&a).unwrap();
            std::fs::remove_file(&b).unwrap();
        }
    }

    #[test]
    fn response_is_signal_plus_small_noise() {
        let spec = SyntheticSpec::synthetic1_scaled(40, 200, 20);
        let ds = generate_synthetic(&spec, 4);
        let beta = ds.beta_star.as_ref().unwrap();
        let mut xb = vec![0.0f32; 40];
        ds.x.matvec(beta, &mut xb);
        let resid: f64 = ds
            .y
            .iter()
            .zip(&xb)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        // noise sd 0.01 over 40 samples → ‖noise‖ ≈ 0.063
        assert!(resid < 0.2, "residual norm {resid}");
        assert!(ops::nrm2(&ds.y) > 1.0);
    }
}
