//! Binary data set serialization.
//!
//! Simple little-endian container (magic `TLFREDS1`) so generated sets can
//! be cached on disk by the CLI (`tlfre generate`) and reloaded by benches
//! without regeneration cost. Layout:
//!
//! ```text
//! magic[8] | name_len u32 | name utf-8 | n u64 | p u64 | g u64
//! | group sizes u64×g | has_beta u8 | X f32×(n·p) col-major
//! | y f32×n | beta f32×p (if has_beta)
//! ```

use super::Dataset;
use crate::groups::GroupStructure;
use crate::linalg::DenseMatrix;
use crate::bail;
use crate::error::{Context, Result};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"TLFREDS1";

fn write_u32(w: &mut impl Write, v: u32) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn write_u64(w: &mut impl Write, v: u64) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn write_f32s(w: &mut impl Write, xs: &[f32]) -> Result<()> {
    // bulk-copy through a byte view for speed
    let bytes = unsafe {
        std::slice::from_raw_parts(xs.as_ptr() as *const u8, xs.len() * 4)
    };
    w.write_all(bytes)?;
    Ok(())
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn read_f32s(r: &mut impl Read, n: usize) -> Result<Vec<f32>> {
    let mut out = vec![0.0f32; n];
    let bytes = unsafe {
        std::slice::from_raw_parts_mut(out.as_mut_ptr() as *mut u8, n * 4)
    };
    r.read_exact(bytes)?;
    // On a big-endian host we'd need a swap; this codebase targets LE
    // (x86-64 / aarch64 LE), assert it at compile time.
    #[cfg(target_endian = "big")]
    compile_error!("dataset IO assumes a little-endian target");
    Ok(out)
}

/// Save a data set to `path`.
pub fn save(ds: &Dataset, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC)?;
    let name = ds.name.as_bytes();
    write_u32(&mut w, name.len() as u32)?;
    w.write_all(name)?;
    write_u64(&mut w, ds.n() as u64)?;
    write_u64(&mut w, ds.p() as u64)?;
    write_u64(&mut w, ds.groups.n_groups() as u64)?;
    for g in 0..ds.groups.n_groups() {
        write_u64(&mut w, ds.groups.size(g) as u64)?;
    }
    w.write_all(&[ds.beta_star.is_some() as u8])?;
    write_f32s(&mut w, ds.x.data())?;
    write_f32s(&mut w, &ds.y)?;
    if let Some(b) = &ds.beta_star {
        write_f32s(&mut w, b)?;
    }
    w.flush()?;
    Ok(())
}

/// Load a data set from `path`.
pub fn load(path: &Path) -> Result<Dataset> {
    let f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{path:?}: not a TLFre dataset (bad magic)");
    }
    let name_len = read_u32(&mut r)? as usize;
    if name_len > 4096 {
        bail!("{path:?}: corrupt header (name length {name_len})");
    }
    let mut name = vec![0u8; name_len];
    r.read_exact(&mut name)?;
    let name = String::from_utf8(name).context("dataset name not utf-8")?;
    let n = read_u64(&mut r)? as usize;
    let p = read_u64(&mut r)? as usize;
    let g = read_u64(&mut r)? as usize;
    if n == 0 || p == 0 || g == 0 || n > 1 << 24 || p > 1 << 28 {
        bail!("{path:?}: implausible dimensions {n}×{p} ({g} groups)");
    }
    let mut sizes = Vec::with_capacity(g);
    for _ in 0..g {
        sizes.push(read_u64(&mut r)? as usize);
    }
    if sizes.iter().sum::<usize>() != p {
        bail!("{path:?}: group sizes do not sum to p");
    }
    let mut has_beta = [0u8; 1];
    r.read_exact(&mut has_beta)?;
    let xdata = read_f32s(&mut r, n * p)?;
    let y = read_f32s(&mut r, n)?;
    let beta_star = if has_beta[0] != 0 { Some(read_f32s(&mut r, p)?) } else { None };
    Ok(Dataset {
        name,
        x: DenseMatrix::from_col_major(n, p, xdata),
        y,
        groups: GroupStructure::from_sizes(&sizes),
        beta_star,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate_synthetic, SyntheticSpec};

    #[test]
    fn roundtrip() {
        let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(10, 40, 8), 5);
        let dir = std::env::temp_dir().join("tlfre_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("rt.bin");
        save(&ds, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.name, ds.name);
        assert_eq!(back.x.data(), ds.x.data());
        assert_eq!(back.y, ds.y);
        assert_eq!(back.beta_star, ds.beta_star);
        assert_eq!(back.groups, ds.groups);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn rejects_garbage_file() {
        let dir = std::env::temp_dir().join("tlfre_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("garbage.bin");
        std::fs::write(&path, b"not a dataset at all").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn rejects_truncated_file() {
        let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(8, 16, 4), 6);
        let dir = std::env::temp_dir().join("tlfre_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trunc.bin");
        save(&ds, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }
}
