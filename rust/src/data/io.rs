//! Binary data set serialization.
//!
//! Simple little-endian container (magic `TLFREDS1`) so generated sets can
//! be cached on disk by the CLI (`tlfre generate`) and reloaded by benches
//! without regeneration cost — and, since the out-of-core work, mapped
//! directly by [`crate::linalg::MmapDenseMatrix`]. Layout:
//!
//! ```text
//! magic[8] | name_len u32 | name utf-8 | n u64 | p u64 | g u64
//! | group sizes u64×g | has_beta u8 | pad 0–3 ×0u8
//! | X f32×(n·p) col-major | y f32×n | beta f32×p (if has_beta)
//! ```
//!
//! The pad is the minimal run of zero bytes that brings the X payload to a
//! 4-byte-aligned file offset, so an `mmap` of the file (page-aligned base)
//! can reinterpret the payload as `&[f32]` directly. Its width is a pure
//! function of the header (`name_len`, `g`), so reader and writer agree
//! without storing it. `y` and `beta` follow immediately and inherit the
//! alignment (`4·n·p` and `4·n` are multiples of 4).
//!
//! Two write paths share this layout:
//!
//! - [`save`] — one-shot, for an in-RAM [`Dataset`];
//! - [`DatasetWriter`] — the block writer: `create` emits the header, then
//!   any number of [`DatasetWriter::write_cols`] calls append column blocks
//!   (each a col-major `&[f32]` whose length is a multiple of `n`), and
//!   [`DatasetWriter::finish`] appends `y`/`beta` after validating that
//!   exactly `p` columns were written. Memory use is bounded by the caller's
//!   block size, so arbitrarily large files can be produced (see
//!   [`crate::data::synthetic::generate_synthetic_streaming`]).
//!
//! Both paths write to a `<name>.tmp` sibling and **atomically rename into
//! place on `finish`**: a crashed or killed write can never leave a partial
//! file at the target path (a partial file whose length happened to match
//! some header would otherwise pass [`check_len`] by accident). A writer
//! dropped without `finish` removes its temp file best-effort.
//!
//! [`load`] validates the header *and* the actual file length against the
//! dimensions before allocating anything, so a truncated or hand-edited
//! file fails loudly instead of driving an OOM-sized `Vec` or a short map.

// The f32 payloads are bulk-copied through byte views with no endianness
// conversion; on a big-endian host that would silently load garbage, so
// refuse to build there (targets are x86-64 / aarch64 LE).
#[cfg(target_endian = "big")]
compile_error!("dataset IO assumes a little-endian target");

use super::Dataset;
use crate::bail;
use crate::error::{Context, Result};
use crate::groups::GroupStructure;
use crate::linalg::{DenseMatrix, MmapDenseMatrix};
use std::io::{BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"TLFREDS1";

fn write_u32(w: &mut impl Write, v: u32) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn write_u64(w: &mut impl Write, v: u64) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn write_f32s(w: &mut impl Write, xs: &[f32]) -> Result<()> {
    // bulk-copy through a byte view for speed (LE-only; guarded above)
    // SAFETY: `xs` is a live, initialized `&[f32]`; reinterpreting it as
    // `len * 4` bytes stays inside the allocation, u8 has no alignment or
    // validity requirements, and the borrow of `xs` pins the data for the
    // lifetime of `bytes`.
    let bytes = unsafe {
        std::slice::from_raw_parts(xs.as_ptr() as *const u8, xs.len() * 4)
    };
    w.write_all(bytes)?;
    Ok(())
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn read_f32s(r: &mut impl Read, n: usize) -> Result<Vec<f32>> {
    let mut out = vec![0.0f32; n];
    // SAFETY: `out` owns an initialized allocation of exactly `n * 4`
    // bytes; viewing it as `&mut [u8]` stays in bounds, every bit pattern
    // is a valid f32, and the exclusive borrow of `out` prevents aliasing
    // while `bytes` lives.
    let bytes = unsafe {
        std::slice::from_raw_parts_mut(out.as_mut_ptr() as *mut u8, n * 4)
    };
    r.read_exact(bytes)?;
    Ok(out)
}

/// Zero pad after `has_beta` that 4-byte-aligns the X payload. A pure
/// function of the header prefix length, so both sides compute it.
fn x_pad(header_bytes: u64) -> u64 {
    (4 - header_bytes % 4) % 4
}

/// Parsed `TLFREDS1` header with the byte offsets of each payload.
#[derive(Debug, Clone)]
pub struct DatasetHeader {
    pub name: String,
    pub n: usize,
    pub p: usize,
    pub group_sizes: Vec<usize>,
    pub has_beta: bool,
    /// Byte offset of the col-major f32 X payload (always 4-aligned).
    pub x_offset: u64,
    /// Byte offset of the y payload.
    pub y_offset: u64,
    /// Byte offset of the β* payload, when `has_beta`.
    pub beta_offset: Option<u64>,
    /// Total file length implied by the dimensions.
    pub expected_len: u64,
}

/// Read and validate the header fields from `r` (positioned at byte 0).
/// Leaves `r` positioned at `x_offset` (the pad is consumed).
fn parse_header(r: &mut impl Read, path: &Path) -> Result<DatasetHeader> {
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{path:?}: not a TLFre dataset (bad magic)");
    }
    let name_len = read_u32(r)? as usize;
    if name_len > 4096 {
        bail!("{path:?}: corrupt header (name length {name_len})");
    }
    let mut name = vec![0u8; name_len];
    r.read_exact(&mut name)?;
    let name = String::from_utf8(name).context("dataset name not utf-8")?;
    let n = read_u64(r)? as usize;
    let p = read_u64(r)? as usize;
    let g = read_u64(r)? as usize;
    if n == 0 || p == 0 || g == 0 || n > 1 << 24 || p > 1 << 28 {
        bail!("{path:?}: implausible dimensions {n}×{p} ({g} groups)");
    }
    let mut sizes = Vec::with_capacity(g);
    for _ in 0..g {
        sizes.push(read_u64(r)? as usize);
    }
    if sizes.iter().sum::<usize>() != p {
        bail!("{path:?}: group sizes do not sum to p");
    }
    let mut has_beta = [0u8; 1];
    r.read_exact(&mut has_beta)?;
    let has_beta = has_beta[0] != 0;

    let header_bytes = 8 + 4 + name_len as u64 + 8 * 3 + 8 * g as u64 + 1;
    let pad = x_pad(header_bytes);
    let mut padb = [0u8; 4];
    r.read_exact(&mut padb[..pad as usize])?;
    let x_offset = header_bytes + pad;
    // n ≤ 2²⁴ and p ≤ 2²⁸ keep all of this well inside u64.
    let y_offset = x_offset + 4 * (n as u64) * (p as u64);
    let beta_offset = has_beta.then_some(y_offset + 4 * n as u64);
    let expected_len = y_offset + 4 * n as u64 + if has_beta { 4 * p as u64 } else { 0 };
    Ok(DatasetHeader {
        name,
        n,
        p,
        group_sizes: sizes,
        has_beta,
        x_offset,
        y_offset,
        beta_offset,
        expected_len,
    })
}

/// Check the header's implied length against the file's actual length.
/// Runs before any payload-sized allocation or mapping.
fn check_len(h: &DatasetHeader, actual: u64, path: &Path) -> Result<()> {
    if actual != h.expected_len {
        bail!(
            "{path:?}: file length {actual} does not match header \
             ({}×{} groups={} has_beta={} ⇒ {} bytes); truncated or corrupt",
            h.n,
            h.p,
            h.group_sizes.len(),
            h.has_beta,
            h.expected_len
        );
    }
    Ok(())
}

/// Read and length-validate a `TLFREDS1` header without touching payloads.
pub fn read_header(path: &Path) -> Result<DatasetHeader> {
    let f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let actual = f.metadata()?.len();
    let mut r = BufReader::new(f);
    let h = parse_header(&mut r, path)?;
    check_len(&h, actual, path)?;
    Ok(h)
}

/// Temp sibling `<name>.tmp` in the target's directory — same filesystem,
/// so the `finish` rename is atomic.
fn temp_sibling(path: &Path) -> std::path::PathBuf {
    let mut name = path
        .file_name()
        .map(|s| s.to_os_string())
        .unwrap_or_else(|| std::ffi::OsString::from("dataset"));
    name.push(".tmp");
    path.with_file_name(name)
}

/// Bounded-memory block writer for the `TLFREDS1` layout (see module doc).
///
/// Writes stream to a temp sibling; the target path only comes into
/// existence — complete and length-consistent — at the atomic rename in
/// [`Self::finish`].
pub struct DatasetWriter {
    w: BufWriter<std::fs::File>,
    n: usize,
    p: usize,
    has_beta: bool,
    cols_written: usize,
    tmp_path: std::path::PathBuf,
    final_path: std::path::PathBuf,
    finished: bool,
}

impl DatasetWriter {
    /// Create the temp sibling of `path` and write the header (including
    /// the alignment pad). `path` itself is untouched until [`Self::finish`].
    pub fn create(
        path: &Path,
        name: &str,
        n: usize,
        p: usize,
        group_sizes: &[usize],
        has_beta: bool,
    ) -> Result<DatasetWriter> {
        if n == 0 || p == 0 || group_sizes.is_empty() {
            bail!("DatasetWriter: empty dimensions {n}×{p}");
        }
        if group_sizes.iter().sum::<usize>() != p {
            bail!("DatasetWriter: group sizes do not sum to p={p}");
        }
        let name_b = name.as_bytes();
        if name_b.len() > 4096 {
            bail!("DatasetWriter: name too long ({} bytes)", name_b.len());
        }
        let tmp_path = temp_sibling(path);
        let f = std::fs::File::create(&tmp_path)
            .with_context(|| format!("create {tmp_path:?}"))?;
        let mut w = BufWriter::new(f);
        w.write_all(MAGIC)?;
        write_u32(&mut w, name_b.len() as u32)?;
        w.write_all(name_b)?;
        write_u64(&mut w, n as u64)?;
        write_u64(&mut w, p as u64)?;
        write_u64(&mut w, group_sizes.len() as u64)?;
        for &s in group_sizes {
            write_u64(&mut w, s as u64)?;
        }
        w.write_all(&[has_beta as u8])?;
        let header_bytes = 8 + 4 + name_b.len() as u64 + 8 * 3 + 8 * group_sizes.len() as u64 + 1;
        let pad = x_pad(header_bytes);
        w.write_all(&[0u8; 4][..pad as usize])?;
        Ok(DatasetWriter {
            w,
            n,
            p,
            has_beta,
            cols_written: 0,
            tmp_path,
            final_path: path.to_path_buf(),
            finished: false,
        })
    }

    /// Append a col-major block of whole columns (`len` multiple of `n`).
    pub fn write_cols(&mut self, block: &[f32]) -> Result<()> {
        if block.len() % self.n != 0 {
            bail!("write_cols: block length {} not a multiple of n={}", block.len(), self.n);
        }
        let k = block.len() / self.n;
        if self.cols_written + k > self.p {
            bail!("write_cols: {} columns exceed p={}", self.cols_written + k, self.p);
        }
        write_f32s(&mut self.w, block)?;
        self.cols_written += k;
        Ok(())
    }

    /// Append `y` (and `beta` when declared), flush, and atomically rename
    /// the temp file onto the target path. Fails unless exactly `p` columns
    /// were streamed; on failure the target path is never created.
    pub fn finish(mut self, y: &[f32], beta: Option<&[f32]>) -> Result<()> {
        if self.cols_written != self.p {
            bail!("finish: wrote {} of {} columns", self.cols_written, self.p);
        }
        if y.len() != self.n {
            bail!("finish: y length {} ≠ n={}", y.len(), self.n);
        }
        if self.has_beta != beta.is_some() {
            bail!("finish: beta presence does not match header");
        }
        write_f32s(&mut self.w, y)?;
        if let Some(b) = beta {
            if b.len() != self.p {
                bail!("finish: beta length {} ≠ p={}", b.len(), self.p);
            }
            write_f32s(&mut self.w, b)?;
        }
        self.w.flush()?;
        std::fs::rename(&self.tmp_path, &self.final_path)
            .with_context(|| format!("rename {:?} into place", self.tmp_path))?;
        self.finished = true;
        Ok(())
    }
}

impl Drop for DatasetWriter {
    fn drop(&mut self) {
        // Abandoned (or errored) write: best-effort cleanup of the temp
        // file. A hard kill skips this, but then only the `.tmp` sibling
        // is left behind — the target path never holds a partial file.
        if !self.finished {
            let _ = std::fs::remove_file(&self.tmp_path);
        }
    }
}

/// Save a data set to `path`.
pub fn save(ds: &Dataset, path: &Path) -> Result<()> {
    let sizes: Vec<usize> =
        (0..ds.groups.n_groups()).map(|g| ds.groups.size(g)).collect();
    let mut w = DatasetWriter::create(
        path,
        &ds.name,
        ds.n(),
        ds.p(),
        &sizes,
        ds.beta_star.is_some(),
    )?;
    w.write_cols(ds.x.data())?;
    w.finish(&ds.y, ds.beta_star.as_deref())
}

/// Load a data set from `path` into RAM.
pub fn load(path: &Path) -> Result<Dataset> {
    let f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let actual = f.metadata()?.len();
    let mut r = BufReader::new(f);
    let h = parse_header(&mut r, path)?;
    check_len(&h, actual, path)?;
    let xdata = read_f32s(&mut r, h.n * h.p)?;
    let y = read_f32s(&mut r, h.n)?;
    let beta_star = if h.has_beta { Some(read_f32s(&mut r, h.p)?) } else { None };
    Ok(Dataset {
        name: h.name,
        x: DenseMatrix::from_col_major(h.n, h.p, xdata),
        y,
        groups: GroupStructure::from_sizes(&h.group_sizes),
        beta_star,
    })
}

/// A dataset whose X payload stays on disk behind [`MmapDenseMatrix`];
/// only `y`, the group structure, and (optionally) β* are resident.
pub struct MmapDataset {
    pub name: String,
    pub x: MmapDenseMatrix,
    pub y: Vec<f32>,
    pub groups: GroupStructure,
    pub beta_star: Option<Vec<f32>>,
}

/// Open `path` with the X payload memory-mapped instead of loaded.
pub fn open_mmap(path: &Path) -> Result<MmapDataset> {
    let h = read_header(path)?;
    let x = MmapDenseMatrix::from_file(path, h.x_offset, h.n, h.p)?;
    let f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut r = BufReader::new(f);
    r.seek(SeekFrom::Start(h.y_offset))?;
    let y = read_f32s(&mut r, h.n)?;
    let beta_star = if h.has_beta { Some(read_f32s(&mut r, h.p)?) } else { None };
    Ok(MmapDataset {
        name: h.name,
        x,
        y,
        groups: GroupStructure::from_sizes(&h.group_sizes),
        beta_star,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate_synthetic, SyntheticSpec};

    fn tmp(file: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("tlfre_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(file)
    }

    /// Exercises the unsafe byte-view blocks in `write_f32s`/`read_f32s`
    /// without touching the filesystem — the io coverage that runs under
    /// Miri (the file-backed tests below are gated off there).
    #[test]
    fn f32_byte_views_roundtrip_in_memory() {
        let xs: Vec<f32> = (0..37).map(|i| (i as f32) * 0.5 - 3.25).collect();
        let mut buf: Vec<u8> = Vec::new();
        write_f32s(&mut buf, &xs).unwrap();
        assert_eq!(buf.len(), xs.len() * 4);
        let mut r = std::io::Cursor::new(buf);
        let back = read_f32s(&mut r, xs.len()).unwrap();
        assert_eq!(back, xs);
        // Short input surfaces as an error, never as garbage f32s.
        let mut short = std::io::Cursor::new(vec![0u8; 7]);
        assert!(read_f32s(&mut short, 2).is_err());
    }

    #[test]
    #[cfg_attr(miri, ignore)] // real-file round trip
    fn roundtrip() {
        let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(10, 40, 8), 5);
        let path = tmp("rt.bin");
        save(&ds, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.name, ds.name);
        assert_eq!(back.x.data(), ds.x.data());
        assert_eq!(back.y, ds.y);
        assert_eq!(back.beta_star, ds.beta_star);
        assert_eq!(back.groups, ds.groups);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    #[cfg_attr(miri, ignore)] // real-file round trip
    fn x_payload_is_four_byte_aligned() {
        let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(8, 16, 4), 6);
        let path = tmp("aligned.bin");
        save(&ds, &path).unwrap();
        let h = read_header(&path).unwrap();
        assert_eq!(h.x_offset % 4, 0);
        assert_eq!(h.y_offset % 4, 0);
        assert_eq!(h.expected_len, std::fs::metadata(&path).unwrap().len());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    #[cfg_attr(miri, ignore)] // real-file round trip
    fn rejects_garbage_file() {
        let path = tmp("garbage.bin");
        std::fs::write(&path, b"not a dataset at all").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    #[cfg_attr(miri, ignore)] // real-file round trip
    fn rejects_truncated_file() {
        let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(8, 16, 4), 6);
        let path = tmp("trunc.bin");
        save(&ds, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        assert!(load(&path).is_err());
        assert!(read_header(&path).is_err());
        assert!(open_mmap(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    #[cfg_attr(miri, ignore)] // real-file round trip
    fn rejects_hand_edited_dimensions_before_allocating() {
        // Inflate `n` in the header of an otherwise valid file: the length
        // check must fail fast instead of trusting n·p into a huge Vec/map.
        let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(8, 16, 4), 7);
        let path = tmp("edited.bin");
        save(&ds, &path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let n_off = 8 + 4 + ds.name.len(); // magic | name_len | name
        bytes[n_off..n_off + 8].copy_from_slice(&(1u64 << 23).to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = load(&path).unwrap_err();
        assert!(format!("{err:#}").contains("does not match header"));
        assert!(open_mmap(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    #[cfg_attr(miri, ignore)] // real-file round trip
    fn block_writer_matches_one_shot_save() {
        let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(10, 40, 8), 9);
        let a = tmp("oneshot.bin");
        let b = tmp("blocks.bin");
        save(&ds, &a).unwrap();
        let sizes: Vec<usize> =
            (0..ds.groups.n_groups()).map(|g| ds.groups.size(g)).collect();
        let mut w =
            DatasetWriter::create(&b, &ds.name, ds.n(), ds.p(), &sizes, true).unwrap();
        // Stream in uneven blocks: 3 + 3 + … columns.
        let n = ds.n();
        let mut j = 0;
        while j < ds.p() {
            let k = (ds.p() - j).min(3);
            w.write_cols(&ds.x.data()[j * n..(j + k) * n]).unwrap();
            j += k;
        }
        w.finish(&ds.y, ds.beta_star.as_deref()).unwrap();
        assert_eq!(std::fs::read(&a).unwrap(), std::fs::read(&b).unwrap());
        std::fs::remove_file(&a).unwrap();
        std::fs::remove_file(&b).unwrap();
    }

    #[test]
    #[cfg_attr(miri, ignore)] // real-file round trip
    fn block_writer_rejects_wrong_column_count() {
        let path = tmp("short.bin");
        let _ = std::fs::remove_file(&path);
        let mut w = DatasetWriter::create(&path, "t", 4, 6, &[3, 3], false).unwrap();
        w.write_cols(&vec![0.0; 4 * 2]).unwrap();
        assert!(w.finish(&[0.0; 4], None).is_err());
        // A failed finish never creates the target, and the errored
        // writer's drop removed its temp sibling.
        assert!(!path.exists());
        assert!(!temp_sibling(&path).exists());
    }

    #[test]
    #[cfg_attr(miri, ignore)] // real-file round trip
    fn unfinished_writer_leaves_no_readable_file() {
        let path = tmp("killed.bin");
        let _ = std::fs::remove_file(&path);
        {
            let mut w = DatasetWriter::create(&path, "t", 4, 6, &[3, 3], false).unwrap();
            w.write_cols(&vec![0.0; 4 * 3]).unwrap();
            // Mid-write, the target path must not exist yet — a reader
            // (or a kill) at this instant can never observe a partial
            // file there.
            assert!(!path.exists());
            assert!(read_header(&path).is_err());
            // Simulated crash: drop without finish.
        }
        assert!(!path.exists(), "abandoned write must not create the target");
        assert!(!temp_sibling(&path).exists(), "abandoned temp file not cleaned up");
    }
}
