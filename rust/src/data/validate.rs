//! Pre-solve input validation with typed errors.
//!
//! A long-running engine cannot let one NaN row poison a whole path solve
//! (every duality gap goes NaN, every screening radius is garbage, and the
//! output *looks* like a model), and a zero column or an empty group makes
//! the screening geometry degenerate (TLFre divides by `‖x_j‖` and
//! `‖X_g‖`). This module runs **before any solve** and rejects such inputs
//! with a typed [`DataError`] naming the exact offending coordinate —
//! never a panic, never silent garbage downstream.
//!
//! The X scan is blocked over columns and fanned out on the worker pool
//! ([`crate::util::pool::parallel_map`]); every chunk is scanned
//! regardless of where faults sit, and the reported error is the one with
//! the **lowest column index** (then lowest row), so the outcome is
//! deterministic at every worker count — the same invariant the solvers
//! keep for their arithmetic.
//!
//! The CLI runs this by default for file-backed inputs (`--file`, where
//! bytes arrive from outside the process) and on request (`--validate-data`)
//! for generated ones; `--no-validate` opts out.

use crate::groups::GroupStructure;
use crate::linalg::DesignMatrix;
use crate::util::pool;

/// Typed validation failure. Converts into [`crate::error::Error`] via the
/// blanket `From<E: std::error::Error + Send + Sync>` impl, so call sites
/// can `?` it straight into the CLI's error chain.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DataError {
    /// `X[row, col]` is NaN or ±∞.
    NonFiniteX { col: usize, row: usize },
    /// `y[row]` is NaN or ±∞.
    NonFiniteY { row: usize },
    /// Column `col` of X is identically zero — screening rules divide by
    /// per-column norms, so the geometry is undefined.
    ZeroNormColumn { col: usize },
    /// Group `group` contains no features — group weights `√n_g` and the
    /// group-level dual norms are undefined.
    EmptyGroup { group: usize },
    /// `X` has `x_rows` rows but `y` has `y_len` entries.
    DimensionMismatch { x_rows: usize, y_len: usize },
}

impl std::fmt::Display for DataError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match *self {
            DataError::NonFiniteX { col, row } => {
                write!(f, "design matrix has a non-finite entry at column {col}, row {row}")
            }
            DataError::NonFiniteY { row } => {
                write!(f, "response vector has a non-finite entry at row {row}")
            }
            DataError::ZeroNormColumn { col } => {
                write!(f, "design-matrix column {col} is identically zero (zero norm)")
            }
            DataError::EmptyGroup { group } => {
                write!(f, "group {group} is empty (zero features)")
            }
            DataError::DimensionMismatch { x_rows, y_len } => {
                write!(f, "design matrix has {x_rows} rows but y has {y_len} entries")
            }
        }
    }
}

impl std::error::Error for DataError {}

/// Columns per scan chunk. Small enough to spread work across the pool on
/// mid-size problems, large enough that per-chunk buffer allocation
/// (`rows` floats) is amortized over many column sweeps.
const SCAN_BLOCK_COLS: usize = 256;

/// Scan one contiguous column range, returning the lowest-(col, row)
/// finding inside it (non-finite beats zero-norm within a column — the
/// non-finite entry is the root cause).
fn scan_cols<M: DesignMatrix>(x: &M, j0: usize, j1: usize) -> Option<DataError> {
    let n = x.rows();
    let mut buf = vec![0.0f32; n];
    for j in j0..j1 {
        x.col_to_dense(j, &mut buf);
        let mut all_zero = true;
        for (i, &v) in buf.iter().enumerate() {
            if !v.is_finite() {
                return Some(DataError::NonFiniteX { col: j, row: i });
            }
            if v != 0.0 {
                all_zero = false;
            }
        }
        if all_zero && n > 0 {
            return Some(DataError::ZeroNormColumn { col: j });
        }
    }
    None
}

/// Validate `y` alone: finite everywhere.
pub fn validate_y(y: &[f32]) -> Result<(), DataError> {
    match y.iter().position(|v| !v.is_finite()) {
        Some(row) => Err(DataError::NonFiniteY { row }),
        None => Ok(()),
    }
}

/// Validate a design matrix / response pair: dimensions agree, every entry
/// of X and y is finite, and no column of X is identically zero. The X
/// scan is pool-parallel over column blocks; the reported error is
/// deterministic (lowest column, then lowest row) at every worker count.
pub fn validate_xy<M: DesignMatrix>(x: &M, y: &[f32]) -> Result<(), DataError> {
    if x.rows() != y.len() {
        return Err(DataError::DimensionMismatch { x_rows: x.rows(), y_len: y.len() });
    }
    validate_y(y)?;
    let p = x.cols();
    let blocks: Vec<(usize, usize)> = (0..p)
        .step_by(SCAN_BLOCK_COLS.max(1))
        .map(|j0| (j0, (j0 + SCAN_BLOCK_COLS).min(p)))
        .collect();
    // Every block is scanned; the blocks vector is in ascending column
    // order and parallel_map preserves order, so the first Some is the
    // lowest-column finding regardless of thread count.
    let findings = pool::parallel_map(&blocks, |&(j0, j1)| scan_cols(x, j0, j1));
    match findings.into_iter().flatten().next() {
        Some(err) => Err(err),
        None => Ok(()),
    }
}

/// [`validate_xy`] plus group-structure degeneracy checks: every group must
/// contain at least one feature (the structure's covering of `p` columns
/// is already asserted by construction in [`GroupStructure`]).
pub fn validate_problem<M: DesignMatrix>(
    x: &M,
    y: &[f32],
    groups: &GroupStructure,
) -> Result<(), DataError> {
    for (g, (s, e)) in groups.ranges().iter().enumerate() {
        if e <= s {
            return Err(DataError::EmptyGroup { group: g });
        }
    }
    validate_xy(x, y)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::DenseMatrix;
    use crate::util::Rng;

    fn clean(n: usize, p: usize, seed: u64) -> (DenseMatrix, Vec<f32>) {
        let mut rng = Rng::seed_from_u64(seed);
        let x = DenseMatrix::from_fn(n, p, |_, _| rng.gaussian() as f32);
        let y: Vec<f32> = (0..n).map(|_| rng.gaussian() as f32).collect();
        (x, y)
    }

    #[test]
    fn clean_data_passes() {
        let (x, y) = clean(20, 600, 7);
        let g = GroupStructure::uniform(600, 60);
        assert_eq!(validate_problem(&x, &y, &g), Ok(()));
    }

    #[test]
    fn nan_in_x_reports_lowest_coordinate() {
        let (x, y) = clean(10, 520, 8);
        let mut x = x;
        // Two faults; the lower column must win at every worker count.
        x.set(3, 500, f32::NAN);
        x.set(7, 137, f32::INFINITY);
        assert_eq!(validate_xy(&x, &y), Err(DataError::NonFiniteX { col: 137, row: 7 }));
    }

    #[test]
    fn nan_in_y_reported() {
        let (x, mut y) = clean(12, 30, 9);
        y[5] = f32::NEG_INFINITY;
        assert_eq!(validate_xy(&x, &y), Err(DataError::NonFiniteY { row: 5 }));
    }

    #[test]
    fn zero_column_reported() {
        let (x, y) = clean(9, 40, 10);
        let mut x = x;
        for i in 0..9 {
            x.set(i, 17, 0.0);
        }
        assert_eq!(validate_xy(&x, &y), Err(DataError::ZeroNormColumn { col: 17 }));
    }

    #[test]
    fn nonfinite_beats_zero_norm_in_same_column() {
        let (x, y) = clean(9, 40, 11);
        let mut x = x;
        for i in 0..9 {
            x.set(i, 17, 0.0);
        }
        x.set(4, 17, f32::NAN);
        assert_eq!(validate_xy(&x, &y), Err(DataError::NonFiniteX { col: 17, row: 4 }));
    }

    #[test]
    fn dimension_mismatch_reported() {
        let (x, y) = clean(10, 20, 12);
        assert_eq!(
            validate_xy(&x, &y[..9]),
            Err(DataError::DimensionMismatch { x_rows: 10, y_len: 9 })
        );
    }

    #[test]
    fn error_converts_into_crate_error() {
        let (x, mut y) = clean(6, 10, 13);
        y[0] = f32::NAN;
        let run = || -> crate::error::Result<()> {
            validate_xy(&x, &y)?;
            Ok(())
        };
        let err = run().unwrap_err();
        assert!(format!("{err:#}").contains("non-finite"), "{err:#}");
    }
}
