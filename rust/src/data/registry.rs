//! Simulated stand-ins for the paper's real data sets.
//!
//! None of the seven real sets the paper evaluates are reachable from this
//! offline environment (ADNI is restricted-access; the rest would need
//! downloads), so each is replaced by a seeded generator matching the
//! screening-relevant geometry — dimensions, group layout, column-norm
//! spread, sign structure and response construction. See DESIGN.md §5 for
//! the substitution table and rationale.
//!
//! `scale ∈ (0, 1]` shrinks the feature dimension for the reduced default
//! bench profile (the sample dimension and all recipes are kept); 1.0
//! reproduces the paper's dimensions exactly.

use super::synthetic::{
    generate_sparse_synthetic, generate_synthetic, SparseDataset, SparseSyntheticSpec,
    SyntheticSpec,
};
use super::Dataset;
use crate::bail;
use crate::error::Result;
use crate::groups::GroupStructure;
use crate::linalg::DenseMatrix;
use crate::util::Rng;

/// Resolve a dataset name to a generated [`Dataset`] — the single name
/// registry behind the CLI's `--dataset` flag and the serve-mode
/// [`crate::server::api::DatasetSpec`].
pub fn resolve_dataset(name: &str, seed: u64, scale: f64) -> Result<Dataset> {
    let ds = match name {
        "synthetic1" => generate_synthetic(
            &SyntheticSpec::synthetic1_scaled(
                250,
                scaled(10_000, scale),
                scaled(10_000, scale) / 10,
            ),
            seed,
        ),
        "synthetic2" => generate_synthetic(
            &SyntheticSpec::synthetic2_scaled(
                250,
                scaled(10_000, scale),
                scaled(10_000, scale) / 10,
            ),
            seed,
        ),
        "adni-gmv" => RealDataset::AdniGmv.generate(scale, seed),
        "adni-wmv" => RealDataset::AdniWmv.generate(scale, seed),
        "breast-cancer" => RealDataset::BreastCancer.generate(scale, seed),
        "leukemia" => RealDataset::Leukemia.generate(scale, seed),
        "prostate" => RealDataset::Prostate.generate(scale, seed),
        "pie" => RealDataset::Pie.generate(scale, seed),
        "mnist" => RealDataset::Mnist.generate(scale, seed),
        "svhn" => RealDataset::Svhn.generate(scale, seed),
        other => bail!(
            "unknown dataset '{other}' (synthetic1|synthetic2|adni-gmv|adni-wmv|breast-cancer|leukemia|prostate|pie|mnist|svhn; 'sparse1' is CSC-native — see resolve_sparse_dataset)"
        ),
    };
    Ok(ds)
}

/// The CSC-native `sparse1` workload at the same scaled dimensions as
/// [`resolve_dataset`]'s synthetic sets (deterministic in `seed`).
pub fn resolve_sparse_dataset(seed: u64, scale: f64, density: f64) -> SparseDataset {
    let p = scaled(10_000, scale);
    generate_sparse_synthetic(&SparseSyntheticSpec::new(250, p, p / 10, density), seed)
}

/// Round `p·scale` to a multiple of 10 (keeps uniform groups divisible).
pub fn scaled(p: usize, scale: f64) -> usize {
    (((p as f64 * scale) / 10.0).round() as usize * 10).max(20)
}

/// The paper's real data sets (Tables 2–3, Figures 3–5).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum RealDataset {
    /// ADNI SNPs, grey-matter-volume response (747 × 426040, 94765 groups).
    AdniGmv,
    /// ADNI SNPs, white-matter-volume response.
    AdniWmv,
    /// Breast cancer gene expression (44 × 7129), ±1 labels.
    BreastCancer,
    /// Leukemia gene expression (52 × 11225), ±1 labels.
    Leukemia,
    /// Prostate cancer mass-spectrometry (132 × 15154), ±1 labels.
    Prostate,
    /// PIE faces self-representation (1024 × 11553), nonnegative.
    Pie,
    /// MNIST digit self-representation (784 × 50000), nonnegative.
    Mnist,
    /// SVHN self-representation (3072 × 99288), nonnegative.
    Svhn,
}

impl RealDataset {
    pub fn name(&self) -> &'static str {
        match self {
            RealDataset::AdniGmv => "ADNI+GMV (sim)",
            RealDataset::AdniWmv => "ADNI+WMV (sim)",
            RealDataset::BreastCancer => "Breast Cancer (sim)",
            RealDataset::Leukemia => "Leukemia (sim)",
            RealDataset::Prostate => "Prostate Cancer (sim)",
            RealDataset::Pie => "PIE (sim)",
            RealDataset::Mnist => "MNIST (sim)",
            RealDataset::Svhn => "SVHN (sim)",
        }
    }

    /// Paper-scale `(n, p)`.
    pub fn full_dims(&self) -> (usize, usize) {
        match self {
            RealDataset::AdniGmv | RealDataset::AdniWmv => (747, 426_040),
            RealDataset::BreastCancer => (44, 7_129),
            RealDataset::Leukemia => (52, 11_225),
            RealDataset::Prostate => (132, 15_154),
            RealDataset::Pie => (1024, 11_553),
            RealDataset::Mnist => (784, 50_000),
            RealDataset::Svhn => (3072, 99_288),
        }
    }

    /// The DPC (nonnegative Lasso) experiment sets of Fig. 5 / Table 3.
    pub fn dpc_sets() -> [RealDataset; 6] {
        [
            RealDataset::BreastCancer,
            RealDataset::Leukemia,
            RealDataset::Prostate,
            RealDataset::Pie,
            RealDataset::Mnist,
            RealDataset::Svhn,
        ]
    }

    /// Generate the simulated data set at the given feature-dimension
    /// scale (`1.0` = paper scale).
    pub fn generate(&self, scale: f64, seed: u64) -> Dataset {
        let (n, p_full) = self.full_dims();
        let mut p = ((p_full as f64 * scale).round() as usize).max(64);
        if matches!(self, RealDataset::Pie | RealDataset::Mnist | RealDataset::Svhn) {
            // Self-representation geometry needs p ≫ n (as in the paper's
            // full dims); a scaled-down p < n flips the problem to an
            // overdetermined one with dense solutions and nothing to
            // screen — not the workload being reproduced.
            p = p.max(2 * n);
        }
        let mut rng = Rng::seed_from_u64(seed ^ 0xDA7A);
        match self {
            RealDataset::AdniGmv | RealDataset::AdniWmv => {
                generate_adni(self.name(), n, p, matches!(self, RealDataset::AdniWmv), &mut rng)
            }
            RealDataset::BreastCancer | RealDataset::Leukemia | RealDataset::Prostate => {
                generate_expression(self.name(), n, p, &mut rng)
            }
            RealDataset::Pie | RealDataset::Mnist | RealDataset::Svhn => {
                generate_image_dictionary(self.name(), n, p, &mut rng)
            }
        }
    }
}

/// ADNI-like SNP design: minor-allele counts {0,1,2} with within-gene LD
/// (latent AR(0.6) gaussian thresholded by allele frequency), gene-sized
/// groups of 2–20 SNPs, group-sparse quantitative response.
fn generate_adni(name: &str, n: usize, p: usize, alt_response: bool, rng: &mut Rng) -> Dataset {
    // Group sizes 2..=20 until p covered (mean ≈ 4.5 matches the paper's
    // 426040/94765 ≈ 4.5 SNPs per gene).
    let mut sizes = Vec::new();
    let mut covered = 0usize;
    while covered < p {
        let s = (2 + rng.below(8) + rng.below(8)).min(20).min(p - covered).max(1);
        sizes.push(s);
        covered += s;
    }
    let groups = GroupStructure::from_sizes(&sizes);
    let mut x = DenseMatrix::zeros(n, p);
    // Per group: latent AR(0.6) across SNPs, threshold by random MAF.
    let rho = 0.6f64;
    let w = (1.0 - rho * rho).sqrt();
    let mut latent = vec![0.0f64; n];
    for (_, s, e) in groups.iter() {
        for v in latent.iter_mut() {
            *v = rng.gaussian();
        }
        for j in s..e {
            let maf = rng.uniform_range(0.05, 0.5);
            // Hardy-Weinberg-ish thresholds on the standard normal.
            let t1 = inv_norm_cdf((1.0 - maf) * (1.0 - maf));
            let t2 = inv_norm_cdf(1.0 - maf * maf);
            for i in 0..n {
                if j > s {
                    latent[i] = rho * latent[i] + w * rng.gaussian();
                }
                let z = latent[i];
                let allele = if z > t2 {
                    2.0
                } else if z > t1 {
                    1.0
                } else {
                    0.0
                };
                x.set(i, j, allele);
            }
        }
    }
    // Center + scale columns (standard GWAS preprocessing) so column norms
    // are comparable — matters for screening geometry.
    standardize_cols(&mut x);
    // Group-sparse β*: 0.5% of genes causal, 1–3 SNPs each.
    let g_cnt = groups.n_groups();
    let causal = rng.sample_indices(g_cnt, (g_cnt / 200).max(5));
    let mut beta = vec![0.0f32; p];
    for &g in &causal {
        let (s, e) = groups.range(g);
        let k = 1 + rng.below((e - s).min(3));
        for &off in &rng.sample_indices(e - s, k) {
            beta[s + off] = rng.normal(0.0, 0.5) as f32;
        }
    }
    let mut y = vec![0.0f32; n];
    x.matvec(&beta, &mut y);
    let noise_sd = if alt_response { 0.8 } else { 0.5 };
    for v in y.iter_mut() {
        *v += rng.normal(0.0, noise_sd) as f32;
    }
    Dataset { name: name.into(), x, y, groups, beta_star: Some(beta) }
}

/// Gene-expression-like design: heavy-tailed (log-normal-ish) positive
/// levels, standardized; binary ±1 labels driven by a small signature.
fn generate_expression(name: &str, n: usize, p: usize, rng: &mut Rng) -> Dataset {
    let mut x = DenseMatrix::zeros(n, p);
    for j in 0..p {
        let base = rng.normal(0.0, 1.0);
        let col = x.col_mut(j);
        for v in col.iter_mut() {
            // log-normal expression level, gene-specific baseline
            *v = ((base + rng.normal(0.0, 0.8)).exp()) as f32;
        }
    }
    standardize_cols(&mut x);
    // Signature: 30 genes decide the label.
    let sig = rng.sample_indices(p, 30);
    let mut score = vec![0.0f64; n];
    for &j in &sig {
        let wgt = rng.normal(0.0, 1.0);
        let col = x.col(j);
        for i in 0..n {
            score[i] += wgt * col[i] as f64;
        }
    }
    let y: Vec<f32> = score.iter().map(|&s| if s >= 0.0 { 1.0 } else { -1.0 }).collect();
    // DPC sets are group-free; give a trivial uniform structure (unused by
    // nonneg Lasso, present so Dataset is self-contained).
    let groups = GroupStructure::from_sizes(&[p]);
    Dataset { name: name.into(), x, y, groups, beta_star: None }
}

/// Image-dictionary design (PIE/MNIST/SVHN self-representation):
/// nonnegative correlated "pixel" columns built from a low-dimensional
/// latent basis + noise, response = a held-out image (nonneg sparse combo
/// of dictionary columns + noise).
fn generate_image_dictionary(name: &str, n: usize, p: usize, rng: &mut Rng) -> Dataset {
    // Latent basis of k "prototype images". Enough prototypes relative to n
    // to keep the dictionary well-conditioned (real image sets are diverse;
    // a rank-deficient dictionary would make the nonneg-Lasso path
    // ill-posed in a way the paper's data is not).
    let k = (n / 3).clamp(4, 256);
    let mut basis = DenseMatrix::zeros(n, k);
    for j in 0..k {
        // smooth-ish prototypes: random walk clipped to ≥ 0
        let col = basis.col_mut(j);
        let mut v = rng.uniform_range(0.0, 1.0);
        for c in col.iter_mut() {
            v = (v + rng.normal(0.0, 0.15)).clamp(0.0, 1.0);
            *c = v as f32;
        }
    }
    let mut x = DenseMatrix::zeros(n, p);
    for j in 0..p {
        // Each dictionary image = one dominant prototype (its "identity")
        // + a weak secondary + strong per-image detail noise. Real image
        // sets are *diverse*: most dictionary columns are far from any
        // given response, which is what gives the DPC rule its margins.
        let mut mix = vec![0.0f32; n];
        crate::linalg::ops::axpy(1.0, basis.col(rng.below(k)), &mut mix);
        crate::linalg::ops::axpy(
            rng.uniform_range(0.0, 0.3) as f32,
            basis.col(rng.below(k)),
            &mut mix,
        );
        let col = x.col_mut(j);
        for i in 0..n {
            col[i] = (mix[i] + rng.uniform_range(0.0, 0.6) as f32).max(0.0);
        }
    }
    // Unit-normalize columns (standard for self-representation work).
    x.normalize_cols();
    // Response: nonneg sparse combination of a few dictionary columns.
    let picks = rng.sample_indices(p, 8);
    let mut y = vec![0.0f32; n];
    for &j in &picks {
        crate::linalg::ops::axpy(rng.uniform_range(0.2, 1.0) as f32, x.col(j), &mut y);
    }
    for v in y.iter_mut() {
        *v = (*v + rng.normal(0.0, 0.01) as f32).max(0.0);
    }
    let groups = GroupStructure::from_sizes(&[p]);
    Dataset { name: name.into(), x, y, groups, beta_star: None }
}

/// Center and unit-scale every column (population sd).
fn standardize_cols(x: &mut DenseMatrix) {
    let n = x.rows();
    for j in 0..x.cols() {
        let col = x.col_mut(j);
        let mean: f64 = col.iter().map(|&v| v as f64).sum::<f64>() / n as f64;
        let mut var = 0.0f64;
        for v in col.iter_mut() {
            *v -= mean as f32;
            var += (*v as f64) * (*v as f64);
        }
        let sd = (var / n as f64).sqrt();
        if sd > 1e-12 {
            let inv = (1.0 / sd) as f32;
            for v in col.iter_mut() {
                *v *= inv;
            }
        }
    }
}

/// Acklam-style rational approximation of the standard normal quantile.
fn inv_norm_cdf(p: f64) -> f64 {
    debug_assert!(p > 0.0 && p < 1.0);
    // Beasley-Springer-Moro.
    let a = [-3.969683028665376e+01, 2.209460984245205e+02, -2.759285104469687e+02,
        1.383577518672690e+02, -3.066479806614716e+01, 2.506628277459239e+00];
    let b = [-5.447609879822406e+01, 1.615858368580409e+02, -1.556989798598866e+02,
        6.680131188771972e+01, -1.328068155288572e+01];
    let c = [-7.784894002430293e-03, -3.223964580411365e-01, -2.400758277161838e+00,
        -2.549732539343734e+00, 4.374664141464968e+00, 2.938163982698783e+00];
    let d = [7.784695709041462e-03, 3.224671290700398e-01, 2.445134137142996e+00,
        3.754408661907416e+00];
    let plow = 0.02425;
    if p < plow {
        let q = (-2.0 * p.ln()).sqrt();
        (((((c[0] * q + c[1]) * q + c[2]) * q + c[3]) * q + c[4]) * q + c[5])
            / ((((d[0] * q + d[1]) * q + d[2]) * q + d[3]) * q + 1.0)
    } else if p <= 1.0 - plow {
        let q = p - 0.5;
        let r = q * q;
        (((((a[0] * r + a[1]) * r + a[2]) * r + a[3]) * r + a[4]) * r + a[5]) * q
            / (((((b[0] * r + b[1]) * r + b[2]) * r + b[3]) * r + b[4]) * r + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -(((((c[0] * q + c[1]) * q + c[2]) * q + c[3]) * q + c[4]) * q + c[5])
            / ((((d[0] * q + d[1]) * q + d[2]) * q + d[3]) * q + 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inv_norm_cdf_known_values() {
        assert!(inv_norm_cdf(0.5).abs() < 1e-6);
        assert!((inv_norm_cdf(0.975) - 1.959964).abs() < 1e-3);
        assert!((inv_norm_cdf(0.025) + 1.959964).abs() < 1e-3);
        assert!(inv_norm_cdf(0.0001) < -3.0);
    }

    #[test]
    fn adni_sim_shape_and_groups() {
        let ds = RealDataset::AdniGmv.generate(0.01, 1);
        assert_eq!(ds.n(), 747);
        assert!(ds.p() >= 4000 && ds.p() <= 4500, "p={}", ds.p());
        // group sizes in [1, 20]
        for g in 0..ds.groups.n_groups() {
            assert!(ds.groups.size(g) <= 20);
        }
        // standardized: column norms ≈ √n
        let norms = ds.x.col_norms();
        let target = (ds.n() as f64).sqrt();
        let near = norms.iter().filter(|&&v| (v - target).abs() < 1.0).count();
        assert!(near > norms.len() * 8 / 10);
    }

    #[test]
    fn adni_gmv_wmv_differ() {
        let a = RealDataset::AdniGmv.generate(0.005, 1);
        let b = RealDataset::AdniWmv.generate(0.005, 1);
        assert_eq!(a.n(), b.n());
        assert_ne!(a.y, b.y);
    }

    #[test]
    fn expression_sets_binary_labels() {
        let ds = RealDataset::BreastCancer.generate(0.05, 2);
        assert_eq!(ds.n(), 44);
        assert!(ds.y.iter().all(|&v| v == 1.0 || v == -1.0));
        assert!(ds.y.iter().any(|&v| v == 1.0));
        assert!(ds.y.iter().any(|&v| v == -1.0));
    }

    #[test]
    fn image_sets_nonnegative_unit_columns() {
        let ds = RealDataset::Pie.generate(0.02, 3);
        assert_eq!(ds.n(), 1024);
        assert!(ds.x.data().iter().all(|&v| v >= 0.0));
        assert!(ds.y.iter().all(|&v| v >= 0.0));
        for nmr in ds.x.col_norms() {
            assert!((nmr - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let a = RealDataset::Leukemia.generate(0.02, 9);
        let b = RealDataset::Leukemia.generate(0.02, 9);
        assert_eq!(a.x.data(), b.x.data());
        assert_eq!(a.y, b.y);
    }

    #[test]
    fn full_dims_match_paper() {
        assert_eq!(RealDataset::AdniGmv.full_dims(), (747, 426_040));
        assert_eq!(RealDataset::Mnist.full_dims(), (784, 50_000));
        assert_eq!(RealDataset::Svhn.full_dims(), (3072, 99_288));
    }
}
