//! End-to-end three-layer driver — proves the full stack composes:
//!
//!   Layer 1 (Pallas screen kernel) → Layer 2 (JAX graph) → HLO text
//!   → [`tlfre::runtime`] (PJRT compile + execute from rust)
//!   → Layer 3 coordinator (ball construction, rules, reduction, solver).
//!
//! Runs the paper's headline experiment on a real small workload (the
//! Synthetic-1 recipe at 100×1000): a 40-point λ-path where the screening
//! sweep `c = Xᵀo` *and* the per-group reductions execute through the
//! AOT-compiled XLA artifact, cross-checked step-by-step against the
//! native rust sweep, followed by the no-screening baseline. Reports the
//! paper's metrics: rejection ratios, screening cost, speedup.
//!
//! Requires `make artifacts`. Run with:
//! `cargo run --release --example e2e_full_stack`

use tlfre::coordinator::path::log_lambda_grid;
use tlfre::coordinator::reduce::ReducedProblem;
use tlfre::coordinator::{run_baseline_path, PathConfig, SolveControls};
use tlfre::data::synthetic::{generate_synthetic, SyntheticSpec};
use tlfre::linalg::ops;
use tlfre::runtime::{artifacts_dir, ArtifactManifest, Runtime, ScreenEngine};
use tlfre::screening::lambda_max::sgl_lambda_max;
use tlfre::screening::tlfre::{apply_rules_from_reductions, screen_ball, TlfreContext};
use tlfre::sgl::{solve_fista, FistaOptions, SglParams, SglProblem};
use tlfre::util::{fmt_duration, Timer};

fn main() -> tlfre::error::Result<()> {
    tlfre::util::logger::init();
    let (n, p, g_cnt) = (100usize, 1000usize, 100usize);
    let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(n, p, g_cnt), 2024);
    println!("workload: {}", ds.describe());

    // ---- Layers 1+2: load the AOT artifact through PJRT -----------------
    let manifest = ArtifactManifest::load(&artifacts_dir())
        .map_err(|e| tlfre::anyhow!("{e:#}\nrun `make artifacts` first"))?;
    let mut rt = Runtime::cpu()?;
    println!("PJRT platform: {}", rt.platform());
    let t = Timer::start();
    let engine = ScreenEngine::for_matrix(&mut rt, &manifest, &ds.x)?;
    println!(
        "screen artifact compiled + X staged in {} (shape {}×{}, group size {})",
        fmt_duration(t.elapsed_s()),
        engine.n(),
        engine.p(),
        engine.group_size
    );

    // ---- Layer 3: the screened path, sweep running through XLA ----------
    let prob = SglProblem::new(&ds.x, &ds.y, &ds.groups);
    let alpha = 1.0;
    let lmax = sgl_lambda_max(&prob, alpha);
    let ctx = TlfreContext::precompute(&prob);
    let grid = log_lambda_grid(lmax.lambda_max, 0.01, 40);
    let opts = FistaOptions { tol: 1e-6, ..Default::default() };

    let mut beta = vec![0.0f32; p];
    let mut lambda_bar = grid[0];
    let mut resid = vec![0.0f32; n];
    let mut corr = vec![0.0f32; p];
    let (mut screen_s, mut solve_s) = (0.0f64, 0.0f64);
    let mut max_xla_native_err = 0.0f64;
    let mut total_rejected = 0usize;
    let mut total_zero = 0usize;

    for &lambda in &grid[1..] {
        // Dual point from the previous solution (feasibility-scaled).
        let ts = Timer::start();
        tlfre::sgl::objective::residual(&prob, &beta, &mut resid);
        let params_bar = SglParams::from_alpha_lambda(alpha, lambda_bar);
        prob.x.matvec_t(&resid, &mut corr);
        let (_gap, s_feas) =
            tlfre::sgl::dual::duality_gap(&prob, &params_bar, &beta, &resid, &corr);
        let theta_bar: Vec<f32> =
            resid.iter().map(|&v| (v as f64 * s_feas / lambda_bar) as f32).collect();
        let ball = screen_ball(&prob, lambda, lambda_bar, &theta_bar, &lmax);

        // The hot sweep — on the XLA engine (Pallas kernel inside).
        let out = engine.run(&rt, &ball.center)?;
        let outcome = apply_rules_from_reductions(
            &prob,
            alpha,
            &out.c,
            &out.group_shrink_sq,
            &out.group_cinf,
            ball.radius,
            &ctx,
        );
        screen_s += ts.elapsed_s();

        // Cross-check the XLA sweep against the native one.
        let mut c_native = vec![0.0f32; p];
        prob.x.matvec_t(&ball.center, &mut c_native);
        for j in 0..p {
            let err = (out.c[j] - c_native[j]).abs() as f64 / (1.0 + c_native[j].abs() as f64);
            max_xla_native_err = max_xla_native_err.max(err);
        }

        // Reduced solve + scatter.
        let ts = Timer::start();
        match ReducedProblem::build(&ds.x, &ds.groups, &outcome) {
            None => beta.fill(0.0),
            Some(red) => {
                let rp = SglProblem::new(&red.x, &ds.y, &red.groups);
                let warm = red.gather(&beta);
                let res = solve_fista(&rp, &SglParams::from_alpha_lambda(alpha, lambda), Some(&warm), &opts);
                red.scatter(&res.beta, &mut beta);
            }
        }
        solve_s += ts.elapsed_s();
        total_rejected += outcome.total_rejected();
        total_zero += ops::count_zeros(&beta).max(1);
        lambda_bar = lambda;
    }

    println!("\n== XLA-screened path ==");
    println!("  mean rejection ratio = {:.3}", total_rejected as f64 / total_zero as f64);
    println!("  max XLA↔native sweep deviation = {max_xla_native_err:.2e}");
    println!("  screen {}  solve {}", fmt_duration(screen_s), fmt_duration(solve_s));
    tlfre::ensure!(max_xla_native_err < 1e-4, "XLA and native sweeps disagree");

    // ---- Baseline -------------------------------------------------------
    let cfg = PathConfig {
        alpha,
        controls: SolveControls {
            n_lambda: 40,
            lambda_min_ratio: 0.01,
            tol: 1e-6,
            ..Default::default()
        },
        ..Default::default()
    };
    let t = Timer::start();
    let baseline = run_baseline_path(&ds.x, &ds.y, &ds.groups, &cfg);
    let base_s = t.elapsed_s();
    println!("\n== baseline (no screening, native) ==");
    println!("  solve {}", fmt_duration(baseline.solve_total_s));

    println!(
        "\nheadline: speedup = {:.2}x  (all three layers composed; python was never invoked)",
        base_s / (screen_s + solve_s)
    );
    Ok(())
}
