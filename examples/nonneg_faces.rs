//! Nonnegative-Lasso face self-representation with DPC screening — the
//! paper's PIE experiment (Section 6.2(d)): a held-out face image is
//! regressed on a dictionary of other faces under a nonnegativity
//! constraint; DPC removes almost all dictionary columns before the
//! solver sees them.
//!
//! Run with: `cargo run --release --example nonneg_faces [--scale 0.05]`

use tlfre::coordinator::{run_dpc_path, run_nonneg_baseline, DpcPathConfig, SolveControls};
use tlfre::data::registry::RealDataset;
use tlfre::nonneg::{lambda_max, NonnegProblem};
use tlfre::util::fmt_duration;

fn main() {
    tlfre::util::logger::init();
    let scale = std::env::args()
        .skip_while(|a| a != "--scale")
        .nth(1)
        .and_then(|v| v.parse::<f64>().ok())
        .unwrap_or(0.03);

    let ds = RealDataset::Pie.generate(scale, 7);
    println!("dataset: {} (nonnegative dictionary, unit columns)", ds.describe());
    let prob = NonnegProblem::new(&ds.x, &ds.y);
    let (lmax, argmax) = lambda_max(&prob);
    println!("λmax = {lmax:.4} at dictionary column {argmax}");

    // Practical solver settings (SLEP-like moderate tolerance); the
    // screened and baseline paths use identical settings so the speedup
    // comparison is apples-to-apples.
    let cfg = DpcPathConfig {
        controls: SolveControls {
            n_lambda: 40,
            lambda_min_ratio: 0.01,
            tol: 1e-4,
            max_iter: 3000,
            ..Default::default()
        },
        ..Default::default()
    };

    println!("\n== DPC-screened path (40 λ values) ==");
    let screened = run_dpc_path(&ds.x, &ds.y, &cfg);
    for s in screened.steps.iter().step_by(5) {
        println!(
            "  λ/λmax={:6.3}  rejection={:5.3}  active={:5}  iters={:4}",
            s.lambda / screened.lambda_max,
            s.rejection,
            s.active_features,
            s.iters
        );
    }
    println!(
        "  mean rejection = {:.3}   screen {}  solve {}",
        screened.mean_rejection(),
        fmt_duration(screened.screen_total_s),
        fmt_duration(screened.solve_total_s)
    );

    println!("\n== baseline (no screening) ==");
    let baseline = run_nonneg_baseline(&ds.x, &ds.y, &cfg);
    println!("  solve {}", fmt_duration(baseline.solve_total_s));

    println!(
        "\nspeedup = {:.2}x",
        baseline.total_s() / screened.total_s()
    );

    // Reconstruction quality at the end of the path (the use case the
    // paper's intro motivates: sparse nonneg self-representation).
    let last = screened.steps.last().unwrap();
    println!(
        "final model: {} active faces out of {} (‖y‖ = {:.3})",
        ds.p() - last.zeros,
        ds.p(),
        tlfre::linalg::ops::nrm2(&ds.y)
    );
}
