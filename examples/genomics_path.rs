//! Genomics scenario: SGL with TLFre on a simulated ADNI-style SNP design
//! (the paper's Section 6.1.2 workload) — ragged gene groups, {0,1,2}
//! allele-count columns with within-gene LD, quantitative imaging response.
//!
//! Demonstrates the part of TLFre the synthetic benches don't: ragged
//! group structures (2–20 SNPs per gene), the α sweep over the paper's
//! seven tan(ψ) values, and screening-pipeline selection through the JSON
//! config's `screen` key (`--screen tlfre|tlfre+gap|gap|strong+kkt|none`
//! forwards into it).
//!
//! Run with: `cargo run --release --example genomics_path [--scale 0.02]
//! [--screen tlfre+gap]`

use tlfre::config::Config;
use tlfre::coordinator::path::{alpha_grid_from_angles, PAPER_ALPHA_ANGLES};
use tlfre::coordinator::{run_tlfre_path, PathConfig, SolveControls};
use tlfre::data::registry::RealDataset;
use tlfre::util::fmt_duration;

fn main() {
    tlfre::util::logger::init();
    let scale = std::env::args()
        .skip_while(|a| a != "--scale")
        .nth(1)
        .and_then(|v| v.parse::<f64>().ok())
        .unwrap_or(0.01);
    // Pipeline selection through the config layer (the `screen` key) —
    // the same JSON a `--config` file would carry.
    let screen = std::env::args()
        .skip_while(|a| a != "--screen")
        .nth(1)
        .unwrap_or_else(|| "tlfre+gap".to_string());
    let base_cfg = Config::from_json(&format!(r#"{{"screen": "{screen}"}}"#))
        .expect("valid screen pipeline (tlfre|tlfre+gap|gap|strong+kkt|none)");
    println!("screening pipeline: {}", base_cfg.screen.as_str());

    for (name, ds) in [
        ("GMV", RealDataset::AdniGmv.generate(scale, 2026)),
        ("WMV", RealDataset::AdniWmv.generate(scale, 2026)),
    ] {
        println!("== ADNI (simulated) + {name}: {} ==", ds.describe());
        let sizes: Vec<usize> = (0..ds.groups.n_groups()).map(|g| ds.groups.size(g)).collect();
        println!(
            "   gene groups: {} (sizes {}..{}, mean {:.1})",
            sizes.len(),
            sizes.iter().min().unwrap(),
            sizes.iter().max().unwrap(),
            sizes.iter().sum::<usize>() as f64 / sizes.len() as f64
        );
        // The paper's α grid; three representatives in the default profile.
        let alphas = alpha_grid_from_angles(&PAPER_ALPHA_ANGLES);
        for (i, &alpha) in [0usize, 3, 6].iter().map(|&i| (i, &alphas[i])) {
            let cfg = PathConfig {
                alpha,
                screen: base_cfg.screen,
                controls: SolveControls {
                    n_lambda: 50,
                    lambda_min_ratio: 0.01,
                    tol: 1e-5,
                    ..Default::default()
                },
                ..Default::default()
            };
            let out = run_tlfre_path(&ds.x, &ds.y, &ds.groups, &cfg);
            let evicted: usize = out.steps.iter().map(|s| s.dynamic_evicted).sum();
            println!(
                "   α=tan({:2}°)  λmax={:8.2}  mean r1={:.3}  mean r1+r2={:.3}  dyn evict={evicted}  screen {}  solve {}",
                PAPER_ALPHA_ANGLES[i],
                out.lambda_max,
                out.mean_r1(),
                out.mean_total_rejection(),
                fmt_duration(out.screen_total_s),
                fmt_duration(out.solve_total_s),
            );
        }
        println!();
    }
}
