//! Quickstart: generate a small Synthetic-1 problem, run the TLFre-screened
//! λ-path and the no-screening baseline, and print rejection ratios and the
//! speedup — the paper's headline workflow in ~40 lines. Then swap the
//! screening pipeline via the JSON config's `screen` key to `tlfre+gap`,
//! which layers GAP-safe screening on top of TLFre and keeps evicting
//! features *inside* the solver as the duality gap shrinks.
//!
//! Run with: `cargo run --release --example quickstart`

use tlfre::config::Config;
use tlfre::coordinator::{run_baseline_path, run_tlfre_path, PathConfig, SolveControls};
use tlfre::data::synthetic::{generate_synthetic, SyntheticSpec};
use tlfre::util::fmt_duration;

fn main() {
    tlfre::util::logger::init();

    // The paper's Synthetic 1 recipe at 1/5 width (single-core friendly).
    let spec = SyntheticSpec::synthetic1_scaled(250, 2000, 200);
    let ds = generate_synthetic(&spec, 42);
    println!("dataset: {}", ds.describe());

    let cfg = PathConfig {
        alpha: 1.0, // tan(45°)
        controls: SolveControls {
            n_lambda: 50,
            lambda_min_ratio: 0.01,
            tol: 1e-6,
            ..Default::default()
        },
        ..Default::default()
    };

    println!("\n== TLFre-screened path ==");
    let screened = run_tlfre_path(&ds.x, &ds.y, &ds.groups, &cfg);
    for s in screened.steps.iter().step_by(7) {
        println!(
            "  λ/λmax={:6.3}  r1={:5.3} r2={:5.3}  active={:5}  solver iters={:4}",
            s.lambda / screened.lambda_max,
            s.r1,
            s.r2,
            s.active_features,
            s.iters
        );
    }
    println!(
        "  mean rejection r1+r2 = {:.3}   screen {}  solve {}",
        screened.mean_total_rejection(),
        fmt_duration(screened.screen_total_s),
        fmt_duration(screened.solve_total_s),
    );

    println!("\n== baseline (no screening) ==");
    let baseline = run_baseline_path(&ds.x, &ds.y, &ds.groups, &cfg);
    println!("  solve {}", fmt_duration(baseline.solve_total_s));

    let speedup = baseline.total_s() / screened.total_s();
    println!(
        "\nspeedup = {:.2}x  (screening itself cost {:.2}% of baseline)",
        speedup,
        100.0 * screened.screen_total_s / baseline.total_s()
    );

    // Pipeline selection via the `screen` config key (exactly what
    // `tlfre solve-path --config cfg.json` would load): `tlfre+gap` adds
    // the GAP-safe static rule plus dynamic in-solver eviction; the per-λ
    // `dyn` counts show features certified zero while the solve ran.
    let json_cfg = Config::from_json(
        r#"{"screen": "tlfre+gap", "n_lambda": 50, "tol": 1e-6, "alphas": [1.0]}"#,
    )
    .expect("valid config");
    let gap_cfg = json_cfg.path_config(1.0);
    println!("\n== tlfre+gap pipeline (screen config key) ==");
    let dynamic = run_tlfre_path(&ds.x, &ds.y, &ds.groups, &gap_cfg);
    let evicted: usize = dynamic.steps.iter().map(|s| s.dynamic_evicted).sum();
    println!(
        "  mean rejection = {:.3}   dynamic evictions = {evicted}   screen {}  solve {}",
        dynamic.mean_total_rejection(),
        fmt_duration(dynamic.screen_total_s),
        fmt_duration(dynamic.solve_total_s),
    );
}
