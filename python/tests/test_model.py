"""Layer-2 graph tests: FISTA-step convergence, screening-graph semantics,
and numpy cross-checks independent of jax."""

import numpy as np
import pytest

from compile import model
from compile.kernels import ref


def make_problem(seed, n=12, p=40, gs=4):
    rng = np.random.default_rng(seed)
    xt = rng.normal(size=(p, n)).astype(np.float32)
    beta_true = np.zeros(p, dtype=np.float32)
    beta_true[rng.choice(p, size=4, replace=False)] = rng.normal(size=4)
    y = (xt.T @ beta_true + 0.01 * rng.normal(size=n)).astype(np.float32)
    return xt, y, gs


def np_objective(xt, y, beta, lam1, lam2, gs):
    r = y - xt.T @ beta
    group_norms = np.linalg.norm(beta.reshape(-1, gs), axis=1)
    return (
        0.5 * float(r @ r)
        + lam1 * np.sqrt(gs) * float(group_norms.sum())
        + lam2 * float(np.abs(beta).sum())
    )


def test_fista_step_graph_converges():
    xt, y, gs = make_problem(0)
    p, n = xt.shape
    step_fn = model.fista_step_graph(gs)
    lip = float(np.linalg.norm(xt.T @ xt, 2)) * 1.01
    lam1 = lam2 = 0.05
    beta = np.zeros(p, dtype=np.float32)
    z = beta.copy()
    t_k = 1.0
    objs = [np_objective(xt, y, beta, lam1, lam2, gs)]
    for _ in range(200):
        scalars = np.array([t_k, 1.0 / lip, lam1, lam2], dtype=np.float32)
        beta, z, t_next = step_fn(xt, y, beta, z, scalars)
        beta, z = np.asarray(beta), np.asarray(z)
        t_k = float(np.asarray(t_next)[0])
        objs.append(np_objective(xt, y, beta, lam1, lam2, gs))
    assert objs[-1] < objs[0]
    # FISTA is non-monotone (momentum), but after 200 steps the final
    # objective must be within a whisker of the best seen.
    assert objs[-1] <= min(objs) * 1.001 + 1e-6

    # KKT check: active features satisfy |x^T r| boundary conditions loosely
    r = y - xt.T @ beta
    c = xt @ r
    for j in range(p):
        if abs(beta[j]) < 1e-7:
            continue
        g = j // gs
        seg = beta[g * gs : (g + 1) * gs]
        expect = lam1 * np.sqrt(gs) * beta[j] / np.linalg.norm(seg) + lam2 * np.sign(beta[j])
        assert abs(c[j] - expect) < 5e-2, f"KKT residual at {j}: {c[j]} vs {expect}"


def test_fista_step_matches_pure_ref():
    xt, y, gs = make_problem(1)
    p, n = xt.shape
    step_fn = model.fista_step_graph(gs)
    rng = np.random.default_rng(3)
    beta = rng.normal(size=p).astype(np.float32)
    z = rng.normal(size=p).astype(np.float32)
    scalars = np.array([1.7, 0.01, 0.3, 0.2], dtype=np.float32)
    b1, z1, t1 = step_fn(xt, y, beta, z, scalars)
    b2, z2, t2 = ref.fista_step_ref(xt, y, beta, z, 1.7, 0.01, 0.3, 0.2, gs)
    np.testing.assert_allclose(b1, b2, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(z1, z2, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(t1)[0], t2, rtol=1e-6)


def test_screen_graph_numpy_crosscheck():
    """The L2 screen graph must agree with a from-scratch numpy version."""
    xt, y, gs = make_problem(2)
    rng = np.random.default_rng(4)
    o = rng.normal(size=xt.shape[1]).astype(np.float32)
    fn = model.tlfre_screen_graph(gs)
    c, gsn, gmax = (np.asarray(v) for v in fn(xt, o))
    c_np = xt.astype(np.float64) @ o.astype(np.float64)
    s_np = np.sign(c_np) * np.maximum(np.abs(c_np) - 1.0, 0.0)
    gsn_np = (s_np.reshape(-1, gs) ** 2).sum(axis=1)
    gmax_np = np.abs(c_np).reshape(-1, gs).max(axis=1)
    np.testing.assert_allclose(c, c_np, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(gsn, gsn_np, rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(gmax, gmax_np, rtol=1e-4, atol=1e-5)


def test_dpc_graph_is_matvec():
    xt, y, gs = make_problem(3)
    rng = np.random.default_rng(5)
    o = rng.normal(size=xt.shape[1]).astype(np.float32)
    (c,) = model.dpc_screen_graph()(xt, o)
    np.testing.assert_allclose(np.asarray(c), xt @ o, rtol=1e-5, atol=1e-5)


def test_objective_graph_matches_numpy():
    xt, y, gs = make_problem(4)
    rng = np.random.default_rng(6)
    beta = rng.normal(size=xt.shape[0]).astype(np.float32)
    (obj,) = model.objective_graph(gs)(xt, y, beta, np.array([0.3, 0.7], np.float32))
    want = np_objective(xt, y, beta, 0.3, 0.7, gs)
    assert abs(float(np.asarray(obj)[0]) - want) < 1e-2 * (1.0 + abs(want))


def test_lowering_produces_parseable_hlo():
    import jax

    xt = jax.ShapeDtypeStruct((32, 8), np.float32)
    o = jax.ShapeDtypeStruct((8,), np.float32)
    text = model.lower_to_hlo_text(model.tlfre_screen_graph(4), (xt, o))
    assert "HloModule" in text
    assert "f32[32,8]" in text
    # return_tuple=True => tuple root
    assert "(f32[32]" in text


def test_lowered_hlo_matches_eager():
    """Execute the lowered computation through jax's own runtime and compare
    with eager execution — validates the AOT path end to end on the python
    side (the rust side has its own integration test)."""
    import jax
    from jax._src.lib import xla_client as xc

    rng = np.random.default_rng(7)
    xt = rng.normal(size=(32, 8)).astype(np.float32)
    o = rng.normal(size=(8,)).astype(np.float32)
    fn = model.tlfre_screen_graph(4)
    eager = [np.asarray(v) for v in fn(xt, o)]

    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct(xt.shape, xt.dtype), jax.ShapeDtypeStruct(o.shape, o.dtype)
    )
    compiled = lowered.compile()
    out = [np.asarray(v) for v in compiled(xt, o)]
    for a, b in zip(eager, out):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)
