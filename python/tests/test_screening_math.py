"""Cross-language validation of the screening mathematics in pure numpy.

Independent re-derivation of λmax (Lemma 9), the Theorem 12 ball, the
Theorem 15 closed form and the (L1)/(L2) rules — then the safety property
is asserted against a from-scratch numpy proximal-gradient SGL solver.
This duplicates (on purpose) what the rust test suite proves, guarding
against a shared-misreading of the paper between the two implementations.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st


# ---------------------------------------------------------------------------
# numpy reference implementation (no jax)

def shrink(w, g):
    return np.sign(w) * np.maximum(np.abs(w) - g, 0.0)


def sgl_prox(v, t_l1, t_l2w, gs):
    s = shrink(v, t_l1).reshape(-1, gs)
    nrm = np.linalg.norm(s, axis=1, keepdims=True)
    scale = np.where(nrm > t_l2w, (nrm - t_l2w) / np.maximum(nrm, 1e-300), 0.0)
    return (s * scale).reshape(-1)


def solve_sgl(x, y, lam1, lam2, gs, iters=6000):
    """Plain proximal gradient (slow, exact enough for tiny problems)."""
    n, p = x.shape
    lip = np.linalg.norm(x, 2) ** 2
    beta = np.zeros(p)
    step = 1.0 / lip
    for _ in range(iters):
        grad = x.T @ (x @ beta - y)
        beta = sgl_prox(beta - step * grad, step * lam2, step * lam1 * np.sqrt(gs), gs)
    return beta


def rho_group(z_desc, alpha, n_g):
    """Bisection on ||S_1(z/rho)|| = alpha*sqrt(n_g)."""
    a2n = alpha * alpha * n_g
    f = lambda rho: float(np.sum(np.maximum(z_desc / rho - 1.0, 0.0) ** 2)) - a2n
    hi = float(z_desc[0])
    lo = hi / 2
    while f(lo) <= 0:
        lo /= 2
        if lo < 1e-280:
            return 0.0
    for _ in range(200):
        mid = (lo + hi) / 2
        if f(mid) > 0:
            lo = mid
        else:
            hi = mid
    return (lo + hi) / 2


def lambda_max(x, y, alpha, gs):
    c = x.T @ y
    rhos = []
    for g in range(x.shape[1] // gs):
        z = np.sort(np.abs(c[g * gs : (g + 1) * gs]))[::-1]
        rhos.append(rho_group(z, alpha, gs) if z[0] > 0 else 0.0)
    return max(rhos), int(np.argmax(rhos)), c


def tlfre_screen(x, y, alpha, lam, lam_bar, beta_bar, lmax, gstar, gs):
    """Theorem 17 in numpy. Returns keep mask."""
    n, p = x.shape
    theta_bar = (y - x @ beta_bar) / lam_bar
    if lam_bar >= lmax * (1 - 1e-12):
        cg = x[:, gstar * gs : (gstar + 1) * gs].T @ (y / lmax)
        nvec = x[:, gstar * gs : (gstar + 1) * gs] @ shrink(cg, 1.0)
    else:
        nvec = y / lam_bar - theta_bar
    v = y / lam - theta_bar
    nn = float(nvec @ nvec)
    vperp = v - (float(v @ nvec) / nn) * nvec if nn > 1e-30 else v
    o = theta_bar + 0.5 * vperp
    radius = 0.5 * float(np.linalg.norm(vperp))
    c = x.T @ o
    keep = np.ones(p, dtype=bool)
    col_norms = np.linalg.norm(x, axis=0)
    for g in range(p // gs):
        seg = c[g * gs : (g + 1) * gs]
        rg = radius * np.linalg.norm(x[:, g * gs : (g + 1) * gs], 2)
        cinf = float(np.max(np.abs(seg)))
        if cinf > 1.0:
            s_star = float(np.linalg.norm(shrink(seg, 1.0))) + rg
        else:
            s_star = max(cinf + rg - 1.0, 0.0)
        if s_star < alpha * np.sqrt(gs):
            keep[g * gs : (g + 1) * gs] = False
        else:
            for j in range(g * gs, (g + 1) * gs):
                if abs(c[j]) + radius * col_norms[j] <= 1.0:
                    keep[j] = False
    return keep


# ---------------------------------------------------------------------------

def make_problem(seed, n=15, p=24, gs=4):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, p))
    beta = np.zeros(p)
    beta[rng.choice(p, size=3, replace=False)] = rng.normal(size=3)
    y = x @ beta + 0.01 * rng.normal(size=n)
    return x, y, gs


@given(seed=st.integers(0, 10_000), alpha=st.floats(0.2, 3.0))
@settings(max_examples=15, deadline=None)
def test_lambda_max_boundary(seed, alpha):
    x, y, gs = make_problem(seed)
    lmax, gstar, c = lambda_max(x, y, alpha, gs)
    # at lambda just above lmax the solution is 0
    b = solve_sgl(x, y, alpha * lmax * 1.01, lmax * 1.01, gs, iters=3000)
    assert np.all(b == 0.0), f"nonzero at lambda > lmax: {np.abs(b).max()}"
    # just below, nonzero
    b2 = solve_sgl(x, y, alpha * lmax * 0.97, lmax * 0.97, gs, iters=3000)
    assert np.any(b2 != 0.0)


@given(
    seed=st.integers(0, 10_000),
    alpha=st.floats(0.3, 2.5),
    frac1=st.floats(0.55, 0.98),
    ratio=st.floats(0.5, 0.95),
)
@settings(max_examples=15, deadline=None)
def test_tlfre_safety_numpy(seed, alpha, frac1, ratio):
    """The central claim, fully in numpy: screened => zero at optimum."""
    x, y, gs = make_problem(seed)
    lmax, gstar, _ = lambda_max(x, y, alpha, gs)
    if lmax <= 0:
        pytest.skip("degenerate problem")
    lam1 = lmax * frac1
    lam2 = lam1 * ratio
    beta1 = solve_sgl(x, y, alpha * lam1, lam1, gs)
    keep = tlfre_screen(x, y, alpha, lam2, lam1, beta1, lmax, gstar, gs)
    beta2 = solve_sgl(x, y, alpha * lam2, lam2, gs)
    for j in range(x.shape[1]):
        if not keep[j]:
            assert abs(beta2[j]) < 1e-6, (
                f"seed={seed} alpha={alpha}: feature {j} screened, beta={beta2[j]}"
            )


def test_screening_from_lambda_max_rejects_everything_near_boundary():
    x, y, gs = make_problem(123)
    alpha = 1.0
    lmax, gstar, _ = lambda_max(x, y, alpha, gs)
    keep = tlfre_screen(
        x, y, alpha, lmax * 0.995, lmax, np.zeros(x.shape[1]), lmax, gstar, gs
    )
    # extremely close to lambda_max, only (at most) the argmax group survives
    assert keep.sum() <= gs, f"{keep.sum()} survivors"
