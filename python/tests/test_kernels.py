"""Pallas kernels vs pure-jnp oracles, swept with hypothesis."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import pick_block_p, ref, screen, sgl_prox

SETTINGS = dict(max_examples=40, deadline=None)


def rand_arrays(seed, p, n):
    rng = np.random.default_rng(seed)
    xt = rng.normal(scale=1.5, size=(p, n)).astype(np.float32)
    o = rng.normal(size=(n,)).astype(np.float32)
    return xt, o


@given(
    n=st.integers(1, 24),
    g_total=st.integers(1, 12),
    gs=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_screen_matches_ref(n, g_total, gs, seed):
    p = g_total * gs
    xt, o = rand_arrays(seed, p, n)
    c, gsn, gmax = screen(xt, o, group_size=gs)
    cr, gsnr, gmaxr = ref.screen_ref(xt, o, gs)
    np.testing.assert_allclose(c, cr, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(gsn, gsnr, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(gmax, gmaxr, rtol=1e-5, atol=1e-6)


@given(
    g_total=st.integers(2, 10),
    gs=st.integers(1, 6),
    block_groups=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_screen_block_size_invariance(g_total, gs, block_groups, seed):
    """The result must not depend on the BlockSpec tiling."""
    from hypothesis import assume

    p = g_total * gs
    bp = block_groups * gs
    assume(p % bp == 0)
    xt, o = rand_arrays(seed, p, 8)
    a = screen(xt, o, group_size=gs)
    b = screen(xt, o, group_size=gs, block_p=bp)
    for x, y in zip(a, b):
        np.testing.assert_allclose(x, y, rtol=1e-6, atol=1e-6)


@given(
    g_total=st.integers(1, 16),
    gs=st.integers(1, 8),
    t_l1=st.floats(0.0, 2.0),
    t_l2w=st.floats(0.0, 2.0),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_sgl_prox_matches_ref(g_total, gs, t_l1, t_l2w, seed):
    p = g_total * gs
    rng = np.random.default_rng(seed)
    w = rng.normal(scale=2.0, size=(p,)).astype(np.float32)
    k = sgl_prox(w, t_l1, t_l2w, group_size=gs)
    r = ref.sgl_prox_ref(w, t_l1, t_l2w, gs)
    np.testing.assert_allclose(k, r, rtol=1e-5, atol=1e-6)


def test_prox_zero_thresholds_is_identity():
    rng = np.random.default_rng(0)
    w = rng.normal(size=(24,)).astype(np.float32)
    out = sgl_prox(w, 0.0, 0.0, group_size=4)
    np.testing.assert_allclose(out, w, rtol=1e-6)


def test_prox_huge_threshold_zeroes():
    rng = np.random.default_rng(1)
    w = rng.normal(size=(24,)).astype(np.float32)
    out = np.asarray(sgl_prox(w, 100.0, 0.0, group_size=4))
    assert np.all(out == 0.0)
    out2 = np.asarray(sgl_prox(w, 0.0, 100.0, group_size=4))
    assert np.all(out2 == 0.0)


def test_pick_block_p_properties():
    for p, gs in [(10000, 10), (32, 4), (1000, 10), (7 * 3, 3)]:
        bp = pick_block_p(p, gs)
        assert p % bp == 0
        assert bp % gs == 0
        assert bp <= max(1024, gs)


def test_screen_decomposition_property():
    """Remark 2: xi = P_Binf(xi) + S_1(xi), parts in the right sets."""
    rng = np.random.default_rng(2)
    xi = rng.normal(scale=2.0, size=(64,)).astype(np.float32)
    s = np.asarray(ref.shrink(xi, 1.0))
    proj = xi - s
    assert np.all(np.abs(proj) <= 1.0 + 1e-6)  # P_Binf part in the box
    np.testing.assert_allclose(proj + s, xi, rtol=1e-6)
    # shrink moves toward zero and never overshoots
    assert np.all(np.abs(s) <= np.abs(xi) + 1e-6)
