"""Test bootstrap for the compile-layer suite.

* Puts ``python/`` on ``sys.path`` so ``import compile`` resolves without an
  editable install (the offline container has no pip).
* When ``hypothesis`` is unavailable (it is not in the offline wheel set),
  the property-based modules are skipped at collection instead of erroring.
"""

import importlib.util
import pathlib
import sys

_PY_ROOT = pathlib.Path(__file__).resolve().parents[1]
if str(_PY_ROOT) not in sys.path:
    sys.path.insert(0, str(_PY_ROOT))

collect_ignore = []
if importlib.util.find_spec("hypothesis") is None:
    # Property-sweep modules need hypothesis; skip them cleanly offline.
    collect_ignore += ["test_kernels.py", "test_screening_math.py"]
