"""AOT pipeline: lower the Layer-2 graphs to HLO text artifacts.

Run once at build time (``make artifacts``); the rust runtime discovers
the outputs through ``artifacts/manifest.json``. Python never runs on the
request path.

Usage:
    python -m compile.aot --out-dir ../artifacts [--profile default|test]
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp

from . import model

# (name, n, p, group_size) shape specializations.
#   test:    tiny shapes exercised by the rust integration tests
#   default: the reduced-profile synthetic benchmark shapes + e2e shape
PROFILES = {
    "test": [
        ("tiny", 8, 32, 4),
    ],
    "default": [
        ("tiny", 8, 32, 4),
        ("e2e", 100, 1000, 10),
        ("synth_reduced", 250, 2000, 10),
    ],
    "full": [
        ("tiny", 8, 32, 4),
        ("e2e", 100, 1000, 10),
        ("synth_reduced", 250, 2000, 10),
        ("synth_full", 250, 10000, 10),
    ],
}


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def build_artifacts(shapes, out_dir):
    """Lower every graph for every shape; return manifest entries."""
    entries = []

    def emit(name, kind, fn, args, n, p, group_size):
        text = model.lower_to_hlo_text(fn, args)
        fname = f"{kind}_{name}_n{n}_p{p}_g{group_size}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        entries.append(
            {
                "name": f"{kind}_{name}",
                "file": fname,
                "kind": kind,
                "n": n,
                "p": p,
                "group_size": group_size,
            }
        )
        print(f"  wrote {fname} ({len(text)} chars)")

    for name, n, p, gs in shapes:
        xt = _spec((p, n))
        o = _spec((n,))
        emit(name, "tlfre_screen", model.tlfre_screen_graph(gs), (xt, o), n, p, gs)
        emit(name, "dpc_screen", model.dpc_screen_graph(), (xt, o), n, p, 0)
        emit(
            name,
            "fista_step",
            model.fista_step_graph(gs),
            (xt, _spec((n,)), _spec((p,)), _spec((p,)), _spec((4,))),
            n,
            p,
            gs,
        )
    return entries


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--profile", default="default", choices=sorted(PROFILES))
    # Back-compat single-file mode used by early scaffolding.
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()

    out_dir = args.out_dir
    os.makedirs(out_dir, exist_ok=True)
    shapes = PROFILES[args.profile]
    print(f"AOT lowering {len(shapes)} shape specializations -> {out_dir}")
    entries = build_artifacts(shapes, out_dir)
    manifest = {"version": 1, "artifacts": entries}
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"manifest: {len(entries)} artifacts")


if __name__ == "__main__":
    main()
