"""Layer-2 JAX compute graphs (build-time only — never on the request path).

Each graph composes the Layer-1 Pallas kernels into the computation the
rust coordinator offloads per path step:

* ``tlfre_screen_graph`` — the fused screening sweep: given the staged
  design matrix transpose and the Theorem-12 ball center, produce the
  correlation vector and the per-group reductions the (L1)/(L2) rules
  consume. This is the request-path hot spot.
* ``dpc_screen_graph``  — the DPC sweep (correlations only).
* ``fista_step_graph``  — one full-matrix FISTA iteration (gradient via
  XLA dot ops + the Pallas prox kernel); the no-screening baseline's
  inner loop, used by the e2e example and the L2 perf benches.

All graphs are shape-specialized at lowering time by ``aot.py`` and
exported as HLO text.
"""

import jax
import jax.numpy as jnp

from .kernels import ref, screen, sgl_prox


def tlfre_screen_graph(group_size):
    """Build the screening graph for a fixed uniform group size.

    Returns a function (xt(p,n), o(n,)) -> (c(p,), gsn(G,), gmax(G,)).
    """

    def fn(xt, o):
        return screen(xt, o, group_size=group_size)

    return fn


def dpc_screen_graph():
    """DPC sweep: (xt(p,n), o(n,)) -> (c(p,),)."""

    def fn(xt, o):
        # Reuse the fused kernel with trivial groups of 1 would waste the
        # reduction outputs; a plain dot keeps the HLO minimal and XLA
        # fuses it into a single sweep.
        return (ref.matvec_t_ref(xt, o),)

    return fn


def fista_step_graph(group_size):
    """One FISTA iteration on the full matrix.

    Returns a function
      (xt(p,n), y(n,), beta(p,), z(p,), scalars(4,)) ->
          (beta_new(p,), z_new(p,), t_next(1,))
    where scalars = [t_k, step, lambda1, lambda2].
    """

    def fn(xt, y, beta, z, scalars):
        t_k = scalars[0]
        step = scalars[1]
        lam1 = scalars[2]
        lam2 = scalars[3]
        xz = jnp.einsum("pn,p->n", xt, z)
        grad = xt @ (xz - y)
        w = z - step * grad
        beta_new = sgl_prox(
            w,
            step * lam2,
            step * lam1 * jnp.sqrt(jnp.float32(group_size)),
            group_size=group_size,
        )
        t_next = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * t_k * t_k))
        omega = (t_k - 1.0) / t_next
        z_new = beta_new + omega * (beta_new - beta)
        return beta_new, z_new, jnp.reshape(t_next, (1,))

    return fn


def objective_graph(group_size):
    """SGL primal objective (diagnostics graph used by tests).

    (xt, y, beta, scalars[lam1, lam2]) -> (obj(1,),)
    """

    def fn(xt, y, beta, scalars):
        lam1 = scalars[0]
        lam2 = scalars[1]
        r = y - jnp.einsum("pn,p->n", xt, beta)
        loss = 0.5 * jnp.sum(r * r)
        bg = beta.reshape(-1, group_size)
        gp = jnp.sum(jnp.sqrt(jnp.sum(bg * bg, axis=1))) * jnp.sqrt(
            jnp.float32(group_size)
        )
        l1 = jnp.sum(jnp.abs(beta))
        return (jnp.reshape(loss + lam1 * gp + lam2 * l1, (1,)),)

    return fn


def lower_to_hlo_text(fn, example_args):
    """Lower a jitted function to HLO text (the rust interchange format).

    jax >= 0.5 emits HloModuleProto with 64-bit instruction ids that the
    crate's XLA (xla_extension 0.5.1) rejects; HLO *text* round-trips
    because the parser reassigns ids. ``return_tuple=True`` so the rust
    side always unwraps a tuple.
    """
    from jax._src.lib import xla_client as xc

    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()
