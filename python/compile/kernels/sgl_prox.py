"""Layer-1 Pallas kernel: the exact SGL proximal operator (uniform groups).

Elementwise soft-threshold followed by a per-group soft-threshold — the
composite prox used by every FISTA iteration of the baseline solver. On
TPU this is a pure-VPU kernel; blocks tile the coefficient vector with
group-aligned boundaries so the group norm reduces in-register.

Validated against ``ref.sgl_prox_ref`` (and transitively against the rust
implementation through the e2e example, which cross-checks both).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .screen import pick_block_p


def _prox_kernel(w_ref, t_ref, out_ref, *, group_size):
    w = w_ref[...]                                       # (block_p,)
    t_l1 = t_ref[0]
    t_l2w = t_ref[1]
    s = jnp.sign(w) * jnp.maximum(jnp.abs(w) - t_l1, 0.0)
    sg = s.reshape(-1, group_size)
    norms = jnp.sqrt(jnp.sum(sg * sg, axis=1, keepdims=True))
    scale = jnp.where(norms > t_l2w, (norms - t_l2w) / jnp.maximum(norms, 1e-30), 0.0)
    out_ref[...] = (sg * scale).reshape(-1)


@functools.partial(jax.jit, static_argnames=("group_size", "block_p"))
def sgl_prox(w, t_l1, t_l2w, *, group_size, block_p=None):
    """Exact SGL prox via the Pallas kernel.

    Args:
      w:      (p,) float32 gradient-step point.
      t_l1:   scalar float32 — step·λ₂.
      t_l2w:  scalar float32 — step·λ₁·√group_size.
      group_size: uniform group size dividing p.

    Returns: (p,) float32 prox output.
    """
    p = w.shape[0]
    assert p % group_size == 0
    if block_p is None:
        block_p = pick_block_p(p, group_size)
    t = jnp.stack([jnp.asarray(t_l1, jnp.float32), jnp.asarray(t_l2w, jnp.float32)])
    grid = (p // block_p,)
    kernel = functools.partial(_prox_kernel, group_size=group_size)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_p,), lambda i: (i,)),
            pl.BlockSpec((2,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_p,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((p,), jnp.float32),
        interpret=True,
    )(w, t)
