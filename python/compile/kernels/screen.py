"""Layer-1 Pallas kernel: the fused TLFre screening sweep.

One pass over the design matrix computes, per column block,

    c      = X^T o            (the correlation sweep)
    gsn_g  = ||S_1(c_g)||^2   (group shrink-norms, (L1) rule input)
    gmax_g = ||c_g||_inf      (group sup-norms, Theorem 15 case split)

fused so X is streamed exactly once. On TPU this is the HBM-bandwidth-bound
schedule: column blocks of X tile into VMEM (BlockSpec over the p axis,
block boundaries aligned to group boundaries so each group's reduction
completes inside one block), the (block_p × n)·(n,) product runs on the
MXU, and the shrink/square/segment-sum epilogue on the VPU. DESIGN.md §8
carries the VMEM/roofline estimate.

``interpret=True`` is required on CPU: real TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute. Numerics are validated
against ``ref.screen_ref`` by pytest/hypothesis.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _screen_kernel(x_ref, o_ref, c_ref, gsn_ref, gmax_ref, *, group_size):
    """Kernel body for one (block_p, n) tile of X^T."""
    xt = x_ref[...]                      # (block_p, n)
    o = o_ref[...]                       # (n,)
    c = xt @ o                           # (block_p,)  MXU
    c_ref[...] = c
    a = jnp.abs(c)
    s = jnp.maximum(a - 1.0, 0.0)        # |S_1(c)| elementwise (VPU)
    s2 = (s * s).reshape(-1, group_size)
    gsn_ref[...] = jnp.sum(s2, axis=1)
    gmax_ref[...] = jnp.max(a.reshape(-1, group_size), axis=1)


def pick_block_p(p, group_size, target=1024):
    """Largest group-aligned block size <= target that divides p."""
    best = group_size
    g_total = p // group_size
    for k in range(1, g_total + 1):
        bp = k * group_size
        if p % bp == 0 and bp <= target:
            best = bp
    return best


@functools.partial(jax.jit, static_argnames=("group_size", "block_p"))
def screen(xt, o, *, group_size, block_p=None):
    """Fused screening sweep via the Pallas kernel.

    Args:
      xt: (p, n) float32 design-matrix transpose.
      o:  (n,)  float32 ball center.
      group_size: uniform group size dividing p.
      block_p: columns-of-X per grid step (group-aligned); default
        auto-picked for a ~1 MiB VMEM tile.

    Returns:
      (c, gsn, gmax) — see ``ref.screen_ref``.
    """
    p, n = xt.shape
    assert p % group_size == 0, f"p={p} not divisible by group_size={group_size}"
    if block_p is None:
        block_p = pick_block_p(p, group_size)
    assert p % block_p == 0 and block_p % group_size == 0
    grid = (p // block_p,)
    bg = block_p // group_size
    kernel = functools.partial(_screen_kernel, group_size=group_size)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_p, n), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_p,), lambda i: (i,)),
            pl.BlockSpec((bg,), lambda i: (i,)),
            pl.BlockSpec((bg,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((p,), jnp.float32),
            jax.ShapeDtypeStruct((p // group_size,), jnp.float32),
            jax.ShapeDtypeStruct((p // group_size,), jnp.float32),
        ],
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(xt, o)
