"""Pure-jnp reference oracles for the Pallas kernels.

Every kernel in this package has an oracle here; pytest + hypothesis sweep
shapes/dtypes and assert_allclose the kernel against these. They are also
the "L2 fallback" semantics: the lowered HLO must be numerically equivalent
whether the Pallas kernel or the oracle is used.

Layout convention (matches the rust runtime): the design matrix is passed
as ``xt`` of shape ``(p, n)`` — the transpose of the usual ``(n, p)`` —
because the rust side stores X column-major, which reinterprets zero-copy
as row-major ``(p, n)``.
"""

import jax.numpy as jnp


def shrink(w, gamma):
    """The paper's shrinkage operator S_gamma (eq. (1))."""
    return jnp.sign(w) * jnp.maximum(jnp.abs(w) - gamma, 0.0)


def screen_ref(xt, o, group_size):
    """Fused TLFre screening sweep (reference).

    Args:
      xt: (p, n) design matrix transpose.
      o:  (n,) dual-estimate ball center.
      group_size: uniform group size (p % group_size == 0).

    Returns:
      c:    (p,)  correlations X^T o.
      gsn:  (G,)  per-group ||S_1(c_g)||^2.
      gmax: (G,)  per-group ||c_g||_inf.
    """
    p = xt.shape[0]
    assert p % group_size == 0
    c = xt @ o
    s = shrink(c, 1.0).reshape(-1, group_size)
    gsn = jnp.sum(s * s, axis=1)
    gmax = jnp.max(jnp.abs(c).reshape(-1, group_size), axis=1)
    return c, gsn, gmax


def matvec_t_ref(xt, v):
    """c = X^T v (the DPC screening sweep)."""
    return xt @ v


def sgl_prox_ref(w, t_l1, t_l2w, group_size):
    """Exact SGL prox per uniform group (reference).

    prox_{t(l2w*||.||_2 + l1*||.||_1)} = group-soft-threshold(S_{t*l1}(w)).

    Args:
      w:      (p,) gradient-step point.
      t_l1:   scalar, step * lambda2.
      t_l2w:  scalar, step * lambda1 * sqrt(group_size).
      group_size: uniform group size.
    """
    s = shrink(w, t_l1).reshape(-1, group_size)
    norms = jnp.linalg.norm(s, axis=1, keepdims=True)
    scale = jnp.where(norms > t_l2w, (norms - t_l2w) / jnp.maximum(norms, 1e-30), 0.0)
    return (s * scale).reshape(-1)


def fista_step_ref(xt, y, beta, z, t_k, step, lam1, lam2, group_size):
    """One full FISTA iteration on the SGL problem (reference).

    Returns (beta_new, z_new, t_next).
    """
    xz = jnp.einsum("pn,p->n", xt, z)
    grad = xt @ (xz - y)
    w = z - step * grad
    beta_new = sgl_prox_ref(
        w, step * lam2, step * lam1 * jnp.sqrt(float(group_size)), group_size
    )
    t_next = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * t_k * t_k))
    omega = (t_k - 1.0) / t_next
    z_new = beta_new + omega * (beta_new - beta)
    return beta_new, z_new, t_next
