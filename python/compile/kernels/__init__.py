"""Layer-1 Pallas kernels for the TLFre hot spots.

* ``screen``   — the fused screening sweep (X^T o + shrink + group norms).
* ``sgl_prox`` — the exact SGL proximal operator.
* ``ref``      — pure-jnp oracles for both.
"""

from . import ref  # noqa: F401
from .screen import pick_block_p, screen  # noqa: F401
from .sgl_prox import sgl_prox  # noqa: F401
